"""Fig. 19: per-lane latency tails on the forced-8-device mesh.

The engine prices every lane's trip through a batch with the simulator's
cost constants and bins it on-device into the shared log-bucket histogram
(``DexState.lat_hist``, schema in obs/latency.py, DESIGN.md §12).  This
benchmark drives YCSB-A/B/E through the instrumented engine and exercises
the whole ledger:

* **Path breakdown** — per (op class, outcome path) counts and p50/p99 for
  cache-hit, remote-fetch, peer-peek, offload, stale-forced and shed lanes.
  A peer-peek arm (fig12's divergent fleet policy) shows peeked lanes
  costing more than pure cache hits but no more than the two-sided offload
  walk; the pipelined arm shows the stale-forced re-execution tail that
  batch-synchronous service never pays.
* **Cross-plane percentile gates** — the YCSB-A arm warms one memory
  column under a forced-fetch engine then measures under ``policy="auto"``
  (fig13's part-2 contrast), while the ``Simulator`` samples per-op
  latencies off ``op_clock`` into the identical schema on the identical
  trace.  ``drift.assert_plane_agreement`` gates mesh-vs-sim p50 AND p99
  per op class with one-bucket (2x) slack — percentiles are geometric
  bucket midpoints, so agreement means landing within one bucket.
* **Cost-model audit** — the offload decision's predicted fetch bytes
  (EMA rule) vs realized fetch bytes per (column, level)
  (``DexState.lat_audit``); the mispricing ratio is reported and banded by
  benchmarks/check_perf.py.
* **Zero added collectives** — the latency plane is pure per-device
  arithmetic plus one scatter.  Its blocks are labelled with
  ``routing.trace_phase("dex/lat")``, so the traced program proves it: no
  collective may be attributed to the ``dex/lat`` phase, in the
  synchronous engine or in either half of a pipelined step.
* **Exact conservation** — every arm asserts the measured-window histogram
  delta equals the STAT_OPS delta (each served lane is binned exactly
  once; the pipelined histogram lags one batch and closes at drain).

Run with ``PYTHONPATH=src python benchmarks/fig19_latency_tails.py
[--quick]`` or via the suite: ``python -m benchmarks.run --only
fig19tails``.
"""

from __future__ import annotations

import os
import pathlib
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import dex as dex_mod  # noqa: E402
from repro.core import engine as engine_mod  # noqa: E402
from repro.core import fleet_cache  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core import routing  # noqa: E402
from repro.core.nodes import KEY_MAX, KEY_MIN  # noqa: E402
from repro.compat import make_mesh_compat  # noqa: E402
from repro.core.sim import HostBTree, SimConfig, Simulator  # noqa: E402
from repro.data import ycsb  # noqa: E402

from repro.obs import drift, latency  # noqa: E402
from benchmarks import common  # noqa: E402
from benchmarks.common import engine_with_retries  # noqa: E402

BATCH = 1024
UPDATE_XOR = 0x5A5A
MAX_RETRIES = 4
SCAN_LEN = 24
MC = 32

#: one-bucket slack on geometric-midpoint percentiles: adjacent buckets are
#: exactly 2x apart, so agreement-within-one-bucket is a [0.5, 2] ratio
#: (padded for float fuzz)
LAT_BAND = drift.ratio(0.49, 2.05)

#: the per-op-class percentile gates for the cross-plane YCSB-A arm; scans
#: are excluded by design — the simulator re-traverses root-to-leaf per
#: scan hop while the mesh follows the succ chain, so their modeled costs
#: diverge structurally (the breakdown still reports them)
GATED_CLASSES = ("lookup", "update")


def _mesh_setup(dataset, *, policy="auto", cache_sets=512, ema_decay=0.98,
                p_admit_leaf_pct=10):
    vals = dataset * 7
    pool, meta = pool_mod.build_pool(dataset, vals, level_m=1, fill=0.7,
                                     n_shards=4)
    if len(jax.devices()) >= 8:
        shape, n_route, n_memory = (2, 4), 2, 4
        mid = int(dataset[dataset.size // 2])
        bounds = np.array([KEY_MIN, mid, KEY_MAX], dtype=np.int64)
    else:
        shape, n_route, n_memory = (1, 1), 1, 1
        bounds = np.array([KEY_MIN, KEY_MAX], dtype=np.int64)
    mesh = make_mesh_compat(shape, ("data", "model"))
    cfg = dex_mod.DexMeshConfig(
        route_axes=("data",), memory_axis="model",
        n_route=n_route, n_memory=n_memory,
        cache_sets=cache_sets, cache_ways=4,
        policy=policy, ema_decay=ema_decay,
        p_admit_leaf_pct=p_admit_leaf_pct,
        route_capacity_factor=float(max(2, n_memory)),
    )
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state,
        dex_mod.state_shardings(mesh, cfg),
    )
    sharding = NamedSharding(mesh, P(("data", "model")))
    return pool, meta, mesh, cfg, bounds, state, sharding


def _assert_no_lat_collectives(counts, label):
    """The latency ledger's traced blocks must issue ZERO collectives."""
    phases = counts.get("phases", {})
    assert "dex/lat" not in phases, (
        f"{label}: latency plane issued collectives: {phases['dex/lat']}"
    )


def _fleet_hist(state):
    return np.asarray(state.lat_hist).sum(axis=0).astype(np.int64)


def _fleet_audit(state):
    return np.asarray(state.lat_audit, dtype=np.float64).sum(axis=0)


def _run_arm(wl_name, ops_set, dataset, n_warm, n_meas, batch, *,
             policy="auto", cache_sets=512, p_admit_leaf_pct=10,
             cache_policy=None, tl=None, seed=11):
    """One synchronous engine arm over a ``wl_name`` trace: warm, prime the
    ledger at the measure fence, record each measured batch, capture the
    histogram delta and assert exact conservation against STAT_OPS.
    ``cache_policy`` may be a callable receiving the arm's ``cfg`` (so a
    fleet policy is always built from the config it runs under)."""
    _pool, meta, mesh, cfg, bounds, state, sharding = _mesh_setup(
        dataset, policy=policy, cache_sets=cache_sets,
        p_admit_leaf_pct=p_admit_leaf_pct)
    if callable(cache_policy):
        cache_policy = cache_policy(cfg)
    eng_fn = engine_mod.make_dex_engine(meta, cfg, mesh, ops=ops_set,
                                        max_count=MC,
                                        cache_policy=cache_policy)
    eng = jax.jit(eng_fn)
    wl = ycsb.generate(wl_name, dataset, (n_warm + n_meas) * batch,
                       theta=0.99, seed=seed, scan_len=SCAN_LEN,
                       scan_len_dist="uniform")

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    opc0, kk0, vv0 = ycsb.engine_lanes(wl, 0, batch, update_xor=UPDATE_XOR)
    counts = routing.trace_collective_counts(
        eng_fn, state, jnp.asarray(opc0), jnp.asarray(kk0),
        jnp.asarray(vv0), by_phase=True,
    )
    _assert_no_lat_collectives(counts, f"fig19 {wl_name}")
    if tl is not None:
        tl.meta["collectives_per_batch"] = {
            k: v for k, v in counts.items() if k != "phases"
        }

    stats_warm = None
    hist_warm = None
    for b in range(n_warm + n_meas):
        if b == n_warm:
            jax.block_until_ready(state.stats)
            stats_warm = np.asarray(state.stats).sum(axis=0)
            hist_warm = _fleet_hist(state)
            if tl is not None:
                tl.prime(state.stats)
                tl.prime_latency(state)
        opc, kk, vv = ycsb.engine_lanes(
            wl, b * batch, (b + 1) * batch, update_xor=UPDATE_XOR)
        ob = tl.batch(wl_name) if (tl is not None and b >= n_warm) else None
        if ob is not None:
            with ob:
                state, *_rest = engine_with_retries(
                    eng, state, put, opc, kk, vv,
                    max_retries=MAX_RETRIES, obs=ob)
                ob.counters(state.stats)
        else:
            state, *_rest = engine_with_retries(
                eng, state, put, opc, kk, vv, max_retries=MAX_RETRIES)
    jax.block_until_ready(state.stats)
    stats = np.asarray(state.stats).sum(axis=0) - stats_warm
    hist = _fleet_hist(state) - hist_warm
    if tl is not None:
        tl.capture_latency(state)
    served = int(stats[dex_mod.STAT_OPS])
    assert int(hist.sum()) == served, (
        f"{wl_name}: histogram not conserved — {int(hist.sum())} binned "
        f"lanes vs {served} served ops"
    )
    return dict(hist=hist, audit=_fleet_audit(state), stats=stats,
                counts=counts)


def _run_gated_a(dataset, n_warm, n_meas, batch, tl=None):
    """The cross-plane arm: fig13's warm-column contrast (forced-fetch warm
    sweep, then ``policy="auto"``) so the measured window mixes cache-hit,
    remote-fetch and offload lanes — then both planes' p50/p99 are gated on
    the identical YCSB-A trace, and the offload audit has realized fetch
    bytes to price against."""
    _pool, meta, mesh, cfg_auto, bounds, state, sharding = _mesh_setup(
        dataset, policy="auto", cache_sets=2048, ema_decay=0.5,
        p_admit_leaf_pct=100,
    )
    cfg_fetch = dex_mod.DexMeshConfig(
        **{**cfg_auto.__dict__, "policy": "fetch"})
    eng_fetch = jax.jit(engine_mod.make_dex_engine(
        meta, cfg_fetch, mesh, ops=("lookup", "update"), max_count=1))
    eng_auto_fn = engine_mod.make_dex_engine(
        meta, cfg_auto, mesh, ops=("lookup", "update"), max_count=1)
    eng_auto = jax.jit(eng_auto_fn)

    wl = ycsb.generate("ycsb-a", dataset, n_meas * batch, theta=0.99,
                       seed=11, hotspot=0.1)
    # warm sweep over the hot column's key range (fig13 part 2): its per-
    # (column, level) miss EMA drops below the cost crossover, so the auto
    # phase serves it one-sided while cold columns offload
    s_per = meta.n_subtrees_padded // cfg_auto.n_memory
    hot_n = min(dataset.size,
                -(-dataset.size * s_per // max(meta.n_subtrees, 1)))
    rng_w = np.random.default_rng(23)
    warm_keys = np.concatenate([
        rng_w.permutation(
            dataset[(np.arange(batch) * hot_n // batch + 17 * b) % hot_n]
        )
        for b in range(n_warm)
    ]).astype(np.int64)
    warm_ops = np.zeros(warm_keys.shape, np.int32)
    wl_all = ycsb.Workload(
        ops=np.concatenate([warm_ops, wl.ops]),
        keys=np.concatenate([warm_keys, wl.keys]),
        scan_len=wl.scan_len,
    )

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    opc0, kk0, vv0 = ycsb.engine_lanes(wl_all, 0, batch,
                                       update_xor=UPDATE_XOR)
    counts = routing.trace_collective_counts(
        eng_auto_fn, state, jnp.asarray(opc0), jnp.asarray(kk0),
        jnp.asarray(vv0), by_phase=True,
    )
    _assert_no_lat_collectives(counts, "fig19 gated ycsb-a")
    if tl is not None:
        tl.meta["collectives_per_batch"] = {
            k: v for k, v in counts.items() if k != "phases"
        }

    stats_warm = hist_warm = audit_warm = None
    for b in range(n_warm + n_meas):
        eng = eng_fetch if b < n_warm else eng_auto
        if b == n_warm:
            jax.block_until_ready(state.stats)
            stats_warm = np.asarray(state.stats).sum(axis=0)
            hist_warm = _fleet_hist(state)
            audit_warm = _fleet_audit(state)
            if tl is not None:
                tl.prime(state.stats)
                tl.prime_latency(state)
        opc, kk, vv = ycsb.engine_lanes(
            wl_all, b * batch, (b + 1) * batch, update_xor=UPDATE_XOR)
        ob = tl.batch("ycsb-a") if (tl is not None and b >= n_warm) else None
        if ob is not None:
            with ob:
                state, *_rest = engine_with_retries(
                    eng, state, put, opc, kk, vv,
                    max_retries=MAX_RETRIES, obs=ob)
                ob.counters(state.stats)
        else:
            state, *_rest = engine_with_retries(
                eng, state, put, opc, kk, vv, max_retries=MAX_RETRIES)
    jax.block_until_ready(state.stats)
    stats = np.asarray(state.stats).sum(axis=0) - stats_warm
    hist = _fleet_hist(state) - hist_warm
    audit = _fleet_audit(state) - audit_warm
    if tl is not None:
        tl.capture_latency(state)
    served = int(stats[dex_mod.STAT_OPS])
    assert int(hist.sum()) == served, (
        f"gated ycsb-a: {int(hist.sum())} binned vs {served} served"
    )

    # Plane A on the identical trace, identical knobs (fig13 part 2), with
    # per-op latency sampling into the identical bucket schema
    sim_tree = HostBTree(
        dataset, dataset * 7, fill=0.7, level_m=1,
        n_mem_servers=cfg_auto.n_memory, placement="blocked",
        subtrees_per_server=meta.n_subtrees_padded // cfg_auto.n_memory,
    )
    sim_cfg = SimConfig(
        name="dex-engine", n_compute=cfg_auto.n_devices,
        n_mem_servers=cfg_auto.n_memory, level_m=1,
        write_through=True, offloading=True,
        group_offload=True, group_ema_decay=cfg_auto.ema_decay,
        coherence_batch=batch, route_dispersion=cfg_auto.n_memory,
        p_admit_leaf=cfg_auto.p_admit_leaf_pct / 100.0,
        cache_bytes=cfg_auto.cache_sets * cfg_auto.cache_ways * 1024,
        offload_c=cfg_auto.offload_c,
    )
    sim = Simulator(sim_tree, sim_cfg, seed=3)
    warm = slice(0, n_warm * batch)
    meas = slice(n_warm * batch, (n_warm + n_meas) * batch)
    sim.run(wl_all.ops[warm], wl_all.keys[warm], group_policy="fetch")
    sim.reset_counters()
    sim.run(wl_all.ops[meas], wl_all.keys[meas])
    sim_hist = sim.lat_hist.copy()
    assert int(sim_hist.sum()) == int(sim.totals().ops), (
        int(sim_hist.sum()), int(sim.totals().ops))
    return dict(hist=hist, audit=audit, stats=stats, sim_hist=sim_hist)


def _run_pipe_a(dataset, n_warm, n_meas, batch, tl=None):
    """The pipelined tail arm: the same YCSB-A trace through the
    double-buffered engine.  The overlap window forces stale-caught lanes
    onto the two-sided re-execution path, so the stale_forced bucket column
    fills — a tail the batch-synchronous arm never pays.  Fetch policy (as
    in fig13's sustained arm): under cold-start auto every lane offloads
    and the overlap version check has no cached reads to catch."""
    _pool, meta, mesh, cfg, bounds, state, sharding = _mesh_setup(
        dataset, policy="fetch")
    pipe = engine_mod.make_dex_engine(
        meta, cfg, mesh, ops=("lookup", "update", "insert"), max_count=1,
        pipeline=True)

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    wl = ycsb.generate("ycsb-a", dataset, (n_warm + n_meas) * batch,
                       theta=0.99, seed=11)

    def lanes(b):
        return ycsb.engine_lanes(wl, b * batch, (b + 1) * batch,
                                 update_xor=UPDATE_XOR)

    opc0, kk0, vv0 = lanes(0)
    counts = routing.trace_collective_counts(
        pipe.step_fn, state, pipe.init_carry(batch),
        jnp.asarray(opc0), jnp.asarray(kk0), jnp.asarray(vv0),
        by_phase=True,
    )
    _assert_no_lat_collectives(counts, "fig19 pipelined ycsb-a")
    # the overlap phases carry every collective; the latency plane none
    assert set(counts["phases"]) == {"pipe/front", "pipe/back"}, counts
    if tl is not None:
        tl.meta["collectives_per_batch"] = {
            k: v for k, v in counts.items() if k != "phases"
        }

    pipe.start(state)
    for b in range(n_warm):
        opc, kk, vv = lanes(b)
        pipe.push(put(opc.astype(np.int32)), put(kk), put(vv))
    pipe.drain()
    jax.block_until_ready(pipe.state.stats)
    stats_warm = np.asarray(pipe.state.stats).sum(axis=0)
    hist_warm = _fleet_hist(pipe.state)
    if tl is not None:
        tl.prime(pipe.state.stats)
        tl.prime_latency(pipe.state)

    for b in range(n_warm, n_warm + n_meas):
        opc, kk, vv = lanes(b)
        ob = tl.batch("ycsb-a") if tl is not None else None
        if ob is not None:
            with ob:
                r = pipe.push(put(opc.astype(np.int32)), put(kk), put(vv))
                with ob.phase("pipe/step") as ph:
                    ph.fence(r if r is not None else pipe.state.stats)
                ob.counters(pipe.state.stats)
        else:
            pipe.push(put(opc.astype(np.int32)), put(kk), put(vv))
    pipe.drain()
    jax.block_until_ready(pipe.state.stats)
    stats = np.asarray(pipe.state.stats).sum(axis=0) - stats_warm
    hist = _fleet_hist(pipe.state) - hist_warm
    if tl is not None:
        tl.capture_latency(pipe.state)
    served = int(stats[dex_mod.STAT_OPS])
    # the histogram lags one batch during steady state; drain closed it
    assert int(hist.sum()) == served, (
        f"pipelined ycsb-a: {int(hist.sum())} binned vs {served} served"
    )
    return dict(hist=hist, stats=stats)


def _path_idx(name):
    return latency.PATHS.index(name)


def _rows_for(rows, arm, hist):
    pct = latency.class_percentiles(hist)
    led = latency.ledger(hist)
    for cls in latency.OP_CLASSES:
        if led[cls]["count"] == 0:
            continue
        rows.append(f"mesh,{arm},{cls},p50_s,{pct[cls]['p50']:.3e}")
        rows.append(f"mesh,{arm},{cls},p99_s,{pct[cls]['p99']:.3e}")
        for pname, cell in led[cls]["paths"].items():
            if cell["count"]:
                rows.append(
                    f"mesh,{arm},{cls},share_{pname},{cell['share']:.4f}")
    return rows


def run(quick: bool = False, seed: "int | None" = None):
    base_seed = 0 if seed is None else int(seed)
    n_keys = 30_000 if quick else 60_000
    batch = 512 if quick else BATCH
    dataset = ycsb.make_dataset(n_keys, seed=base_seed)
    on_mesh = len(jax.devices()) >= 8
    rows = ["plane,arm,class,metric,value"]
    summary = {}

    # -- cross-plane gated YCSB-A arm ----------------------------------
    tl_a = common.new_timeline("fig19tails_ycsb-a",
                               devices=len(jax.devices()), batch=batch)
    g = _run_gated_a(dataset, 10 if quick else 14, 4 if quick else 8,
                     batch, tl=tl_a)
    common.finish_timeline(tl_a)
    rows = _rows_for(rows, "ycsb-a", g["hist"])
    mesh_g = latency.percentile_gauges(g["hist"], classes=GATED_CLASSES)
    sim_g = latency.percentile_gauges(g["sim_hist"], classes=GATED_CLASSES)
    for k, v in mesh_g.items():
        summary[f"ycsb-a_{k}"] = v
    for k, v in sim_g.items():
        rows.append(f"sim,ycsb-a,{k.split('_')[-1]},{k[:7]}_s,{v:.3e}")
    if on_mesh:
        # p50 AND p99 per gated op class, one-bucket slack, both planes on
        # the identical trace with the identical pricing constants
        tol = {k: LAT_BAND for k in mesh_g}
        assert set(mesh_g) == set(sim_g), (sorted(mesh_g), sorted(sim_g))
        drift.assert_plane_agreement(mesh_g, sim_g, tol,
                                     label="fig19 latency percentiles")
    audit = latency.audit_report(g["audit"][0], g["audit"][1])
    summary["mispricing_ratio"] = audit["mispricing_ratio"]
    summary["audit_predicted_bytes"] = audit["predicted_bytes"]
    summary["audit_realized_bytes"] = audit["realized_bytes"]
    rows.append(
        f"mesh,ycsb-a,all,mispricing_ratio,{audit['mispricing_ratio']:.4f}")
    if on_mesh:
        # the warm column kept fetching under auto, so the audit must have
        # priced real fetch-side decisions.  The ratio itself is committed
        # to baselines.json (check_perf MODELED band): on this contrast arm
        # the warm sweep drives the EMA near zero, so the zipfian measured
        # phase realizes far more fetch bytes than the rule predicted —
        # exactly the lag the audit exists to expose.  Here only sanity:
        # non-degenerate and finite.
        assert audit["realized_bytes"] > 0, audit
        assert 0.0 < audit["mispricing_ratio"] < 1e3, audit

    # -- breadth arms: YCSB-B (read-heavy), YCSB-E (scan-heavy) --------
    for wl_name, ops_set, n_w, n_m in (
        ("ycsb-b", ("lookup", "update", "insert"), 2, 3),
        ("ycsb-e", ("insert", "scan"), 2, 3),
    ):
        tl = common.new_timeline(f"fig19tails_{wl_name}",
                                 devices=len(jax.devices()), batch=batch)
        arm = _run_arm(wl_name, ops_set, dataset, n_w, n_m, batch, tl=tl)
        common.finish_timeline(tl)
        rows = _rows_for(rows, wl_name, arm["hist"])
        for k, v in latency.percentile_gauges(arm["hist"]).items():
            summary[f"{wl_name}_{k}"] = v

    # -- peer-peek arm: divergent fleet policy on the same trace -------
    tl_pk = common.new_timeline("fig19tails_peek",
                                devices=len(jax.devices()), batch=batch)
    pk = _run_arm(
        "ycsb-a", ("lookup", "update"), dataset, 4, 3, batch,
        policy="fetch", cache_sets=2048, p_admit_leaf_pct=100,
        cache_policy=lambda cfg: fleet_cache.divergent_policy(
            cfg, peek_budget=batch),
        tl=tl_pk)
    common.finish_timeline(tl_pk)
    rows = _rows_for(rows, "peek", pk["hist"])
    peek_lanes = int(pk["hist"][:, _path_idx("peer_peek")].sum())
    summary["peek_lanes"] = float(peek_lanes)
    if on_mesh:
        assert peek_lanes > 0, "divergent arm produced no peer-peek lanes"
        lk = pk["hist"][latency.OP_CLASSES.index("lookup")]
        p50_hit = latency.percentile(lk[_path_idx("cache_hit")], 50.0)
        p50_peek = latency.percentile(lk[_path_idx("peer_peek")], 50.0)
        p50_off = latency.percentile(lk[_path_idx("offload")], 50.0)
        p50_fetch = latency.percentile(lk[_path_idx("remote_fetch")], 50.0)
        slow = max(p50_off, p50_fetch)
        # a peer peek pays a full sibling RPC (t_rpc_base) on top of the
        # lookup, so under the cost model it is the dearest lane: above
        # every direct path, yet within two buckets (4x) of the slowest —
        # peeking relieves memory-server bandwidth, it does not cut latency
        assert p50_hit < p50_peek, (p50_hit, p50_peek)
        assert slow <= p50_peek <= 4.0 * slow, (p50_peek, slow)
        summary["peek_p50_over_slowest"] = p50_peek / slow

    # -- pipelined tail arm --------------------------------------------
    tl_p = common.new_timeline("fig19tails_pipe",
                               devices=len(jax.devices()), batch=batch,
                               mode="pipelined")
    pp = _run_pipe_a(dataset, 2, 6 if quick else 10, batch, tl=tl_p)
    common.finish_timeline(tl_p)
    rows = _rows_for(rows, "pipe", pp["hist"])
    stale = int(pp["hist"][:, _path_idx("stale_forced")].sum())
    stale_g = int(g["hist"][:, _path_idx("stale_forced")].sum())
    summary["pipe_stale_lanes"] = float(stale)
    summary["pipe_stale_share"] = stale / max(int(pp["hist"].sum()), 1)
    rows.append(f"mesh,pipe,all,stale_lanes,{stale}")
    if on_mesh:
        # batch-synchronous service never re-executes a stale read; the
        # overlap window must, under zipfian same-leaf conflicts — the
        # throughput it buys is gated in fig13engine, the tail lives here
        assert stale_g == 0, stale_g
        assert stale > 0, "no stale-forced lanes in the pipelined arm"
        upd = latency.OP_CLASSES.index("update")
        p99_stale = latency.percentile(
            pp["hist"][upd, _path_idx("stale_forced")], 99.0)
        p99_rest = latency.percentile(
            pp["hist"][upd].sum(axis=0)
            - pp["hist"][upd, _path_idx("stale_forced")], 99.0)
        assert p99_stale >= p99_rest, (p99_stale, p99_rest)
        summary["pipe_stale_p99_s"] = p99_stale

    return rows, summary


def main():
    quick = "--quick" in sys.argv
    rows, summary = run(quick=quick)
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k} = {v}")


if __name__ == "__main__":
    main()
