"""Fig. 12 (mesh arm): cooperative fleet caching at equal cache bytes.

``fig12_cache_size`` sweeps the priced Plane-A cache-ratio curves; this
module adds the mesh arm on the forced-8-device mesh (2 route partitions x
4 memory columns).  Two engines run the IDENTICAL hot-set trace at equal
per-chip cache bytes:

* **uniform** — ``cache_policy=None``: every chip admits leaves with the
  same ``p_admit_leaf_pct`` dice, exactly the pre-policy-layer behaviour
  (core/fleet_cache.py keeps this path bit-identical).
* **divergent+peek** — ``fleet_cache.divergent_policy``: each chip skews
  leaf admission toward its own memory column's subtrees (so the four
  siblings of a route row specialise on disjoint quarters of the hot set)
  and, on a local leaf miss for a foreign column, first peeks the sibling
  specialist's cache via a ``MSG_PEEK`` lane piggybacked on the engine's
  existing fused ``all_to_all`` — before paying a remote fetch.

Asserted (8-device mesh):

  * the divergent arm's *effective fleet hit rate* — row needs served
    without a remote row fetch, ``(hits + peer_hits) / (hits + peer_hits
    + peer_misses + fetches)`` — strictly beats the uniform arm's at every
    equal-bytes point where the fleet's aggregate capacity covers the hot
    set (the headline sweep point);
  * peer peeks add ZERO extra collectives per batch: the traced programs
    of both arms hold identical collective counts
    (``routing.trace_collective_counts``) — the peek rides the fused pair
    the write path already pays for;
  * ``STAT_PEER_HITS`` moves on the mesh, the poisonable version check
    notwithstanding (tests/mesh_check.py owns the staleness round trip);
  * the simulator (core/sim.py) pricing the identical trace with the
    mirrored knobs (``fleet_col_affinity``, ``fleet_peek_budget``) agrees
    with the mesh's peer-hit count within the drift band, and its
    divergent arm beats its uniform arm too.

Run with ``PYTHONPATH=src python benchmarks/fig12_fleet_cache.py
[--quick]`` or via the suite: ``python -m benchmarks.run --only
fig12fleet``.
"""

from __future__ import annotations

import os
import pathlib
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import dex as dex_mod  # noqa: E402
from repro.core import engine as engine_mod  # noqa: E402
from repro.core import fleet_cache  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core import routing  # noqa: E402
from repro.core.nodes import KEY_MAX, KEY_MIN  # noqa: E402
from repro.compat import make_mesh_compat  # noqa: E402
from repro.core.sim import HostBTree, SimConfig, Simulator  # noqa: E402
from repro.data import ycsb  # noqa: E402
from repro.obs import drift, registry  # noqa: E402
from benchmarks.common import engine_with_retries  # noqa: E402

MAX_RETRIES = 4
#: leaf admission dice for BOTH arms (the divergent arm's per-column bias
#: multiplies this, clipped to [1, 100] by fleet_cache.leaf_admit)
P_ADMIT = 50
#: update fraction of the hot trace — enough writes that both arms pay the
#: fused all_to_all pair every batch (the round the peek piggybacks on)
UPDATE_FRAC = 0.03
#: hot-set shape: RUNS strided runs of RUN_LEN consecutive keys, sized so
#: the hot leaves exceed ONE chip's rows at the headline sweep point but
#: fit the four-sibling fleet (FANOUT=64, fill=0.7 -> ~45 keys/leaf)
RUNS, RUN_LEN = 480, 16
#: cache_sets sweep (x cache_ways=4 rows/chip); last entry is the headline
#: point where the fleet holds the hot set
SWEEP_QUICK = (16, 64)
SWEEP_FULL = (16, 32, 64)


def _hot_trace(dataset, n_ops, rng):
    """Hot-subset trace: keys drawn uniformly from strided runs spread over
    the whole keyspace (so blocked placement spreads the hot leaves evenly
    across all four memory columns), 3% updates / 97% lookups.  Update
    values rewrite ``key * 7`` so every lookup's expected value stays
    ``key * 7`` for the in-loop spot check."""
    step = max((dataset.size - RUN_LEN) // max(RUNS - 1, 1), 1)
    starts = np.arange(RUNS) * step
    hot = np.unique(
        np.concatenate([dataset[s : s + RUN_LEN] for s in starts])
    ).astype(np.int64)
    kk = rng.choice(hot, size=n_ops).astype(np.int64)
    opc = np.where(
        rng.random(n_ops) < UPDATE_FRAC, ycsb.OP_UPDATE, ycsb.OP_LOOKUP
    ).astype(np.int32)
    return hot, opc, kk


def _setup(dataset, cache_sets):
    vals = dataset * 7
    pool, meta = pool_mod.build_pool(
        dataset, vals, level_m=1, fill=0.7, n_shards=4
    )
    if len(jax.devices()) >= 8:
        shape, n_route, n_memory = (2, 4), 2, 4
        mid = int(dataset[dataset.size // 2])
        bounds = np.array([KEY_MIN, mid, KEY_MAX], dtype=np.int64)
    else:
        shape, n_route, n_memory = (1, 1), 1, 1
        bounds = np.array([KEY_MIN, KEY_MAX], dtype=np.int64)
    mesh = make_mesh_compat(shape, ("data", "model"))
    cfg = dex_mod.DexMeshConfig(
        route_axes=("data",),
        memory_axis="model",
        n_route=n_route,
        n_memory=n_memory,
        cache_sets=cache_sets,
        cache_ways=4,
        policy="fetch",
        p_admit_leaf_pct=P_ADMIT,
        route_capacity_factor=float(max(2, n_memory)),
    )
    sharding = NamedSharding(mesh, P(("data", "model")))
    return pool, meta, mesh, cfg, bounds, sharding


def _mesh_arm(pool, meta, mesh, cfg, bounds, sharding, policy, opc, kk,
              n_warm, n_meas, batch):
    """One engine arm over the shared trace; returns the measured-window
    counter deltas, the effective fleet hit rate and the traced collective
    counts of the steady-state batch."""
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state,
        dex_mod.state_shardings(mesh, cfg),
    )
    eng_fn = engine_mod.make_dex_engine(
        meta, cfg, mesh, ops=("lookup", "update"), max_count=1,
        cache_policy=policy,
    )
    eng = jax.jit(eng_fn)

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    vv = kk * 7
    counts = routing.trace_collective_counts(
        eng_fn, state,
        jnp.asarray(opc[:batch]), jnp.asarray(kk[:batch]),
        jnp.asarray(vv[:batch]),
    )

    stats_warm = None
    for b in range(n_warm + n_meas):
        if b == n_warm:
            jax.block_until_ready(state.stats)
            stats_warm = np.asarray(state.stats).sum(axis=0)
        sl = slice(b * batch, (b + 1) * batch)
        state, found, vals, status, _sk, _sv, _tk, done = engine_with_retries(
            eng, state, put, opc[sl], kk[sl], vv[sl],
            max_retries=MAX_RETRIES,
        )
        # spot check: values are invariant under the trace's updates, so
        # every completed lookup must find key * 7 — a peer-served lane
        # returning a wrong or stale row would fail here
        lk = done & (opc[sl] == ycsb.OP_LOOKUP) & (kk[sl] != KEY_MAX)
        assert found[lk].all(), "hot-set lookup missed"
        assert (vals[lk] == kk[sl][lk] * 7).all(), "wrong value served"
    jax.block_until_ready(state.stats)
    stats = np.asarray(state.stats).sum(axis=0) - stats_warm

    hits = float(stats[dex_mod.STAT_HITS])
    fetches = float(stats[dex_mod.STAT_FETCHES])
    ph = float(stats[dex_mod.STAT_PEER_HITS])
    pm = float(stats[dex_mod.STAT_PEER_MISSES])
    rate = (hits + ph) / max(hits + ph + pm + fetches, 1.0)
    return dict(rate=rate, stats=stats, counts=dict(counts),
                peer_hits=int(ph), peer_misses=int(pm))


def _sim_arm(dataset, meta, cfg, opc, kk, n_warm_ops, *, affinity,
             peek_budget, batch):
    """Plane-A mirror on the identical trace: blocked subtree placement so
    both planes agree on column ownership, per-server admission bias via
    ``fleet_col_affinity`` and the peer-peek hop via
    ``fleet_peek_budget``."""
    tree = HostBTree(
        dataset, dataset * 7, fill=0.7, level_m=1,
        n_mem_servers=cfg.n_memory, placement="blocked",
        subtrees_per_server=meta.n_subtrees_padded // cfg.n_memory,
    )
    sim_cfg = SimConfig(
        name="dex-fleet", n_compute=cfg.n_devices,
        n_mem_servers=cfg.n_memory, level_m=1,
        write_through=True, offloading=False,
        coherence_batch=batch, route_dispersion=cfg.n_memory,
        p_admit_leaf=cfg.p_admit_leaf_pct / 100.0,
        cache_bytes=cfg.cache_sets * cfg.cache_ways * 1024,
        fleet_col_affinity=affinity,
        fleet_peek_budget=peek_budget,
    )
    sim = Simulator(tree, sim_cfg, seed=3)
    sim.run(opc[:n_warm_ops], kk[:n_warm_ops])
    sim.reset_counters()
    sim.run(opc[n_warm_ops:], kk[n_warm_ops:])
    t = sim.totals()
    served = t.local_accesses + t.peer_hits
    denom = served + t.rdma_read + t.peer_misses
    return dict(rate=served / max(denom, 1.0), totals=t)


def run(quick: bool = False, seed: "int | None" = None):
    base_seed = 0 if seed is None else int(seed)
    n_keys = 30_000 if quick else 60_000
    n_warm = 5 if quick else 8
    n_meas = 4 if quick else 6
    batch = 512 if quick else 1024
    sweep = SWEEP_QUICK if quick else SWEEP_FULL
    rng = np.random.default_rng(base_seed + 12)
    dataset = ycsb.make_dataset(n_keys, seed=base_seed)
    hot, opc, kk = _hot_trace(dataset, (n_warm + n_meas) * batch, rng)

    on_mesh = len(jax.devices()) >= 8
    rows = ["plane,arm,cache_sets,metric,value"]
    summary = {}
    headline = sweep[-1]
    for cache_sets in sweep:
        pool, meta, mesh, cfg, bounds, sharding = _setup(dataset, cache_sets)
        div_pol = fleet_cache.divergent_policy(cfg, peek_budget=batch)
        uni = _mesh_arm(pool, meta, mesh, cfg, bounds, sharding, None,
                        opc, kk, n_warm, n_meas, batch)
        div = _mesh_arm(pool, meta, mesh, cfg, bounds, sharding, div_pol,
                        opc, kk, n_warm, n_meas, batch)
        # the peek rides the fused pair the write path already pays for:
        # the two arms' traced programs are collective-for-collective
        # identical — peeking adds NOTHING to the communication plan
        assert div["counts"] == uni["counts"], (div["counts"], uni["counts"])
        assert uni["peer_hits"] == 0 and uni["peer_misses"] == 0, uni

        s_uni = _sim_arm(dataset, meta, cfg, opc, kk, n_warm * batch,
                         affinity=1.0, peek_budget=0, batch=batch)
        s_div = _sim_arm(dataset, meta, cfg, opc, kk, n_warm * batch,
                         affinity=4.0, peek_budget=batch, batch=batch)

        for arm, m, s in (("uniform", uni, s_uni), ("divergent", div, s_div)):
            rows += [
                f"mesh,{arm},{cache_sets},fleet_hit_rate,{m['rate']:.4f}",
                f"mesh,{arm},{cache_sets},peer_hits,{m['peer_hits']}",
                f"mesh,{arm},{cache_sets},peer_misses,{m['peer_misses']}",
                f"sim,{arm},{cache_sets},fleet_hit_rate,{s['rate']:.4f}",
                f"sim,{arm},{cache_sets},peer_hits,"
                f"{int(s['totals'].peer_hits)}",
            ]

        if on_mesh and cache_sets == headline:
            # equal per-chip bytes, strictly better fleet-wide service:
            # the specialised siblings + peek beat every-chip-caches-the-
            # same once the fleet's aggregate capacity covers the hot set
            assert div["rate"] > uni["rate"], (div["rate"], uni["rate"])
            assert s_div["rate"] > s_uni["rate"], (s_div["rate"],
                                                   s_uni["rate"])
            assert div["peer_hits"] > 0, "no peer peeks landed"
            # both planes price the same sibling-specialist rule on the
            # identical trace: peer-hit counts must agree within the band
            drift.assert_plane_agreement(
                registry.snapshot(div["stats"][None, :]),
                s_div["totals"],
                {"peer_hits": drift.ratio(0.25, 4.0)},
                label="fig12fleet peer peeks",
            )

        if cache_sets == headline:
            ph, pm = div["peer_hits"], div["peer_misses"]
            summary["fleet_hit_rate_uniform"] = uni["rate"]
            summary["fleet_hit_rate_divergent"] = div["rate"]
            summary["divergent_gain"] = div["rate"] / max(uni["rate"], 1e-9)
            summary["peer_hit_fraction"] = ph / max(ph + pm, 1)
            summary["peek_extra_collectives"] = float(
                sum(div["counts"].values()) - sum(uni["counts"].values())
            )
            summary["mesh_peer_hits"] = float(ph)
            summary["sim_peer_hits"] = float(s_div["totals"].peer_hits)
            summary["sim_fleet_hit_rate_uniform"] = s_uni["rate"]
            summary["sim_fleet_hit_rate_divergent"] = s_div["rate"]
            summary["sim_divergent_gain"] = s_div["rate"] / max(
                s_uni["rate"], 1e-9
            )
    summary["hot_keys"] = float(hot.size)
    return rows, summary


def main():
    quick = "--quick" in sys.argv
    rows, summary = run(quick=quick)
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k} = {v}")


if __name__ == "__main__":
    main()
