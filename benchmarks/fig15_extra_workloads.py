"""Fig. 15 / Table 3 (extended version): read-intensive-2 (95% lookup / 5%
insert) skewed+uniform, and insert-only (uniform), plus the scan-intensive
mix from Table 1.

Paper claims: DEX 4x/10x/2.4x/6.1x over Sherman/SMART/P-Sherman/P-SMART on
skewed read-intensive-2; 2.8x/56.3x/1.6x/48.4x on scan-intensive (SMART's
one-record-per-leaf trie explodes on scans)."""

from benchmarks.common import HEADER, run_one, seed_kwargs

SYSTEMS = ["dex", "sherman", "p-sherman", "smart", "p-smart"]


def run(quick: bool = False, seed: "int | None" = None):
    skw = seed_kwargs(seed)
    rows = [HEADER]
    summary = {}
    cases = [("read-intensive-2", 0.99), ("scan-intensive", 0.99)]
    if not quick:
        cases += [("read-intensive-2", 0.0), ("insert-only", 0.0)]
    for wl, theta in cases:
        at = {}
        for system in SYSTEMS:
            r = run_one(system, wl, theta=theta, n_ops=20_000, **skw)
            rows.append(r.row())
            at[system] = r.report.mops()
        tag = f"{wl}@{'skew' if theta else 'unif'}"
        for s in SYSTEMS[1:]:
            summary[f"{tag}:dex/{s}"] = at["dex"] / max(at[s], 1e-9)
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k} = {v:.1f}x")


if __name__ == "__main__":
    main()
