"""Benchmark driver: one module per paper table/figure + the LM roofline.

Usage::

    PYTHONPATH=src python -m benchmarks.run            # full suite
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-speed subset
    PYTHONPATH=src python -m benchmarks.run --only fig6,tab2
    PYTHONPATH=src python -m benchmarks.run --quick --json results.json

Each module prints CSV rows plus ``# claim`` comment lines comparing against
the paper's published numbers; EXPERIMENTS.md snapshots these outputs.
``--json`` additionally writes every module's rows/summary (plus timing) to
a machine-readable file — CI uploads it as a perf-trajectory artifact."""

from __future__ import annotations

import argparse
import importlib
import json
import sys
import time
import traceback

MODULES = [
    ("fig6", "benchmarks.fig6_skewed"),
    ("fig6mesh", "benchmarks.fig6_mesh_mixed"),
    ("fig7", "benchmarks.fig7_uniform"),
    ("tab2", "benchmarks.tab2_rdma_stats"),
    ("fig8", "benchmarks.fig8_ablation"),
    ("fig9", "benchmarks.fig9_cache_design"),
    ("fig10", "benchmarks.fig10_repartition"),
    ("fig10meshrep", "benchmarks.fig10_mesh_repartition"),
    ("fig12", "benchmarks.fig12_cache_size"),
    ("fig12fleet", "benchmarks.fig12_fleet_cache"),
    ("fig13", "benchmarks.fig13_offload_threads"),
    ("fig13engine", "benchmarks.fig13_mesh_engine"),
    ("fig14meshload", "benchmarks.fig14_mesh_load"),
    ("fig15", "benchmarks.fig15_extra_workloads"),
    ("fig15mesh", "benchmarks.fig15_mesh_scan"),
    ("fig16", "benchmarks.fig16_key_size"),
    ("fig17", "benchmarks.fig17_skewness"),
    ("fig18", "benchmarks.fig18_admission"),
    ("fig19tails", "benchmarks.fig19_latency_tails"),
    ("fig20leafdirect", "benchmarks.fig20_leaf_direct"),
    ("micro", "benchmarks.index_microbench"),
    ("roofline", "benchmarks.lm_roofline"),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(k for k, _ in MODULES))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows/summaries of every module to PATH")
    ap.add_argument("--seed", type=int, default=None,
                    help="base RNG seed threaded into every module's "
                         "dataset/workload generation, so bench_results.json "
                         "is reproducible across runs (default: each "
                         "module's built-in seed)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="export every mesh benchmark's per-batch metrics "
                         "timeline ({name}.metrics_timeline.json) and "
                         "Perfetto-viewable Chrome trace ({name}.trace.json) "
                         "into DIR")
    args = ap.parse_args(argv)

    from benchmarks import common

    if args.trace_dir:
        common.TRACE_DIR = args.trace_dir

    only = set(args.only.split(",")) if args.only else None
    failures = []
    results = {}
    for key, modname in MODULES:
        if only and key not in only:
            continue
        print(f"\n===== {key} ({modname}) =====")
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            rows, summary = mod.run(quick=args.quick, seed=args.seed)
            print("\n".join(rows))
            for k, v in summary.items():
                print(f"# {k}: {v}")
            results[key] = {
                "rows": rows,
                "summary": {k: float(v) for k, v in summary.items()},
                "seconds": round(time.time() - t0, 2),
            }
            telemetry = common.drain_telemetry()
            if telemetry:
                results[key]["telemetry"] = telemetry
        except Exception as e:
            failures.append((key, e))
            results[key] = {"error": repr(e)}
            common.drain_telemetry()  # don't leak into the next module
            traceback.print_exc()
        print(f"# [{key}] took {time.time() - t0:.1f}s")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {"quick": args.quick, "seed": args.seed, "results": results},
                f, indent=2,
            )
        print(f"# wrote {args.json}")
    if failures:
        print(f"\n{len(failures)} benchmark module(s) failed: "
              f"{[k for k, _ in failures]}")
        sys.exit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
