"""Benchmark driver: one module per paper table/figure + the LM roofline.

Usage::

    PYTHONPATH=src python -m benchmarks.run            # full suite
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-speed subset
    PYTHONPATH=src python -m benchmarks.run --only fig6,tab2

Each module prints CSV rows plus ``# claim`` comment lines comparing against
the paper's published numbers; EXPERIMENTS.md snapshots these outputs."""

from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    ("fig6", "benchmarks.fig6_skewed"),
    ("fig7", "benchmarks.fig7_uniform"),
    ("tab2", "benchmarks.tab2_rdma_stats"),
    ("fig8", "benchmarks.fig8_ablation"),
    ("fig9", "benchmarks.fig9_cache_design"),
    ("fig10", "benchmarks.fig10_repartition"),
    ("fig12", "benchmarks.fig12_cache_size"),
    ("fig13", "benchmarks.fig13_offload_threads"),
    ("fig15", "benchmarks.fig15_extra_workloads"),
    ("fig15mesh", "benchmarks.fig15_mesh_scan"),
    ("fig16", "benchmarks.fig16_key_size"),
    ("fig17", "benchmarks.fig17_skewness"),
    ("fig18", "benchmarks.fig18_admission"),
    ("micro", "benchmarks.index_microbench"),
    ("roofline", "benchmarks.lm_roofline"),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(k for k, _ in MODULES))
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    failures = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        print(f"\n===== {key} ({modname}) =====")
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            rows, summary = mod.run(quick=args.quick)
            print("\n".join(rows))
            for k, v in summary.items():
                print(f"# {k}: {v}")
        except Exception as e:
            failures.append((key, e))
            traceback.print_exc()
        print(f"# [{key}] took {time.time() - t0:.1f}s")
    if failures:
        print(f"\n{len(failures)} benchmark module(s) failed: "
              f"{[k for k, _ in failures]}")
        sys.exit(1)
    print("\nall benchmarks completed")


if __name__ == "__main__":
    main()
