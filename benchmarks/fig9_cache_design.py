"""Fig. 9: cache design choices — centralized-FIFO + eager admission
baseline vs cooling map vs cooling map + lazy leaf admission, at stressed
(small) and default cache sizes, 144 threads, read-intensive.

Paper claims: cooling map +12x/+10x (64MB/256MB caches); +lazy admission
+25%/+21% more."""

from benchmarks.common import HEADER, run_one, seed_kwargs

VARIANTS = [
    ("fifo+eager", dict(centralized_fifo=True, eager_admission=True)),
    ("coolmap+eager", dict(eager_admission=True)),
    ("coolmap+lazy", dict()),
]
# stressed (~2%) and default (~8%) cache ratios mirror 64MB vs 256MB
CACHES = [0.02, 0.08]


def run(quick: bool = False, seed: "int | None" = None):
    skw = seed_kwargs(seed)
    rows = [HEADER]
    summary = {}
    caches = CACHES[:1] if quick else CACHES
    for ratio in caches:
        prev = None
        for label, overrides in VARIANTS:
            r = run_one(
                "dex", "read-intensive", cache_ratio=ratio,
                cfg_overrides=dict(offloading=False, **overrides), **skw,
            )
            rows.append(f"{label}@{ratio:.0%}," + r.row().split(",", 1)[1])
            x = r.report.mops()
            if prev is not None:
                summary[f"{ratio:.0%}:{label}"] = x / max(prev, 1e-9)
            prev = x
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k}: {v:.2f}x over previous variant")


if __name__ == "__main__":
    main()
