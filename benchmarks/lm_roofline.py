"""LM-framework roofline table: reads the dry-run JSON cells
(``dryrun_results/``) and prints the §Roofline table — three terms,
dominant bottleneck, useful-FLOPs ratio, roofline fraction per
(arch x shape x mesh)."""

from __future__ import annotations

import glob
import json
import os

HEADER = (
    "arch,shape,mesh,chips,compute_s,memory_s,collective_s,dominant,"
    "useful_flops_ratio,roofline_fraction,mem_per_chip_GiB"
)


def load_cells(out_dir: str = "dryrun_results"):
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def rows_from_cells(cells):
    rows = [HEADER]
    for c in cells:
        if c.get("status") == "skipped":
            rows.append(
                f"{c['arch']},{c['shape']},{c['mesh']},,,,,SKIP,,,"
            )
            continue
        if c.get("status") != "ok":
            rows.append(
                f"{c['arch']},{c['shape']},{c['mesh']},,,,,FAILED,,,"
            )
            continue
        rows.append(
            f"{c['arch']},{c['shape']},{c['mesh']},{c['chips']},"
            f"{c['compute_term_s']:.4e},{c['memory_term_s']:.4e},"
            f"{c['collective_term_s']:.4e},{c['dominant']},"
            f"{c['useful_flops_ratio']:.3f},{c['roofline_fraction']:.3f},"
            f"{c['per_device_memory_bytes']/2**30:.2f}"
        )
    return rows


def run(quick: bool = False, out_dir: str = "dryrun_results",
        seed: "int | None" = None):
    # deterministic analysis of dry-run artifacts: `seed` (threaded by
    # benchmarks/run.py into every module) has nothing to reseed here
    del seed
    cells = load_cells(out_dir)
    rows = rows_from_cells(cells)
    ok = [c for c in cells if c.get("status") == "ok"]
    summary = {
        "cells_ok": len(ok),
        "cells_skipped": sum(1 for c in cells if c.get("status") == "skipped"),
        "cells_failed": sum(1 for c in cells if c.get("status") == "FAILED"),
    }
    if ok:
        worst = min(ok, key=lambda c: c.get("roofline_fraction", 1e9))
        coll = max(ok, key=lambda c: c.get("collective_term_s", 0))
        summary["worst_roofline"] = (
            f"{worst['arch']}x{worst['shape']}x{worst['mesh']}"
            f"={worst['roofline_fraction']:.3f}"
        )
        summary["most_collective_bound"] = (
            f"{coll['arch']}x{coll['shape']}x{coll['mesh']}"
            f"={coll['collective_term_s']:.3e}s"
        )
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k}: {v}")


if __name__ == "__main__":
    main()
