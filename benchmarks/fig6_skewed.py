"""Fig. 6: throughput under skewed (zipf 0.99) workloads, varying threads.

Paper claims reproduced: DEX outperforms Sherman/SMART/P-Sherman/P-SMART by
2.5-9.6x at 144 threads across read-only/read-intensive/write-intensive/
insert-intensive; SMART's FIFO cache collapses with thread count."""

from benchmarks.common import HEADER, seed_kwargs, sweep_threads

SYSTEMS = ["dex", "sherman", "p-sherman", "smart", "p-smart"]
WORKLOADS = ["read-only", "read-intensive", "write-intensive", "insert-intensive"]
THREADS = [2, 18, 36, 72, 108, 144]


def run(quick: bool = False, seed: "int | None" = None):
    skw = seed_kwargs(seed)
    workloads = WORKLOADS[:2] if quick else WORKLOADS
    rows = [HEADER]
    summary = {}
    for wl in workloads:
        at_max = {}
        for system in SYSTEMS:
            for r in sweep_threads(system, wl, THREADS, **skw):
                rows.append(r.row())
                if r.threads == THREADS[-1]:
                    at_max[system] = r.report.mops()
        for s in SYSTEMS[1:]:
            summary[f"{wl}:dex/{s}"] = at_max["dex"] / max(at_max[s], 1e-9)
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    print("# speedups at 144 threads (paper: 2.5-9.6x):")
    for k, v in summary.items():
        print(f"# {k} = {v:.2f}x")


if __name__ == "__main__":
    main()
