"""Fig. 13: impact of memory-side compute power on opportunistic offloading
(1% cache to force misses, 144 compute threads).

Paper claims: going from 1 to 4 memory-side threads per server cuts RDMA ops
by 56%/49% (RI/WI) and lifts throughput by 40%/55%; offload volume grows
with available memory-side compute."""

from benchmarks.common import HEADER, run_one, seed_kwargs

MEM_THREADS = [1, 2, 4]


def run(quick: bool = False, seed: "int | None" = None):
    skw = seed_kwargs(seed)
    rows = [HEADER]
    summary = {}
    wls = ["read-intensive"] if quick else ["read-intensive", "write-intensive"]
    for wl in wls:
        first = last = None
        for mt in MEM_THREADS:
            r = run_one(
                "dex", wl, cache_ratio=0.01,
                cfg_overrides=dict(mem_threads_per_server=mt), **skw,
            )
            rows.append(f"dex-mt{mt}," + r.row().split(",", 1)[1])
            if first is None:
                first = r
            last = r
        summary[f"{wl}:throughput_gain"] = (
            last.report.mops() / max(first.report.mops(), 1e-9)
        )
        ops_f = first.per_op["reads"] + first.per_op["two_sided"]
        ops_l = last.per_op["reads"] + last.per_op["two_sided"]
        summary[f"{wl}:offload_share_4t"] = last.per_op["two_sided"]
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k}: {v:.3f}")


if __name__ == "__main__":
    main()
