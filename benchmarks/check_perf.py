"""CI perf-regression gate: bench_results.json vs committed baselines.

``benchmarks/baselines.json`` commits, per run mode (``quick``/``full``)
and per benchmark module, a band for every gated summary metric.  This
script re-reads a fresh ``bench_results.json`` (the artifact bench-smoke
already uploads) and fails (exit 1) with a readable delta table when any
gated metric leaves its band — a throughput regression, a modeled-speedup
claim going soft, or a structural count (collective rounds per batch)
changing at all.

Band forms, chosen per metric by the ``GATES`` table below:

* ``{"value": V, "rel_band": [lo, hi]}`` — pass iff ``lo*V <= x <= hi*V``.
  Wall-clock throughputs get wide bands (CI machines vary); simulator-
  modeled numbers are deterministic for a fixed ``--seed`` and get tight
  ones.
* ``{"min": V}`` — absolute floor, independent of any measured baseline
  (e.g. the pipelined engine's modeled speedup must stay >= 1.15x).
* ``{"value": V, "exact": true}`` — structural invariants such as
  collective rounds per engine batch: any drift is a protocol change and
  must be re-committed deliberately.

Refresh workflow (after an intentional perf/protocol change)::

    PYTHONPATH=src python -m benchmarks.run --quick --seed 0 \
        --only fig15mesh,fig6mesh,fig10meshrep,fig14meshload,fig13engine,fig12fleet,fig19tails,fig20leafdirect \
        --json bench_results.json --trace-dir traces
    PYTHONPATH=src python -m benchmarks.check_perf bench_results.json \
        --update-baselines
    git diff benchmarks/baselines.json   # review, then commit

``--self-test`` proves the gate trips: it perturbs an in-memory copy of
the passing results below each band kind and asserts the check fails —
CI runs this dry-run so a silently toothless gate is itself a failure.

Usage::

    PYTHONPATH=src python -m benchmarks.check_perf bench_results.json
    PYTHONPATH=src python -m benchmarks.check_perf bench_results.json \
        [--baselines PATH] [--update-baselines] [--self-test]
"""

from __future__ import annotations

import argparse
import copy
import json
import pathlib
import sys

DEFAULT_BASELINES = pathlib.Path(__file__).parent / "baselines.json"

#: wall-clock throughput on shared CI runners: wide
WALL = ("rel", 0.25, 4.0)
#: simulator/cost-model output, deterministic for a fixed seed: tight
MODELED = ("rel", 0.95, 1.05)
#: mesh-side event counters, deterministic trace but jax-version drift
#: tolerated: medium
COUNTER = ("rel", 0.5, 2.0)
#: static collective structure: any change is a protocol change
EXACT = ("exact",)

#: module -> gated summary metric -> band template used by
#: ``--update-baselines`` (the committed baselines.json is what the check
#: itself reads)
GATES = {
    "fig15mesh": {
        "mesh_scans_per_s": WALL,
        "sim_node_reads_per_op": MODELED,
    },
    "fig6mesh": {
        "ycsb-a_mesh_writes_per_op": MODELED,
        "ycsb-a_sim_writes_per_op": MODELED,
    },
    "fig10meshrep": {
        "live_ops_per_s": WALL,
        "n_repartitions": ("min", 1.0),
        "live_drops": COUNTER,
    },
    "fig14meshload": {
        "smo_ops_per_s": WALL,
        "onmesh_frac": ("min", 0.90),
        "smo_splits": COUNTER,
    },
    "fig12fleet": {
        "fleet_hit_rate_uniform": COUNTER,
        "fleet_hit_rate_divergent": COUNTER,
        "divergent_gain": ("min", 1.01),
        "peer_hit_fraction": COUNTER,
        "peek_extra_collectives": EXACT,
    },
    "fig19tails": {
        # geometric bucket midpoints from the shared log-scale histogram:
        # deterministic for a fixed trace, and a one-bucket move is a 2x
        # jump — the tight band makes any tail drift loud
        "ycsb-a_lat_p50_lookup": MODELED,
        "ycsb-a_lat_p99_lookup": MODELED,
        "ycsb-a_lat_p99_update": MODELED,
        "mispricing_ratio": MODELED,
        "pipe_stale_lanes": ("min", 1.0),
        "peek_lanes": ("min", 1.0),
    },
    "fig13engine": {
        "ycsb-a_engine_ops_per_s": WALL,
        "ycsb-a_engine_a2a": EXACT,
        "ycsb-a_sync_sustained_ops_per_s": WALL,
        "ycsb-a_pipeline_sustained_ops_per_s": WALL,
        "pipeline_wall_ratio": ("min", 0.5),
        "pipeline_stall_lanes": ("min", 1.0),
        "pipeline_modeled_speedup": ("min", 1.15),
        "pipeline_modeled_mops": MODELED,
    },
    "fig20leafdirect": {
        # the leaf-direct claim itself: remote reads per op on YCSB-A must
        # stay strictly below the descent-only arm (the benchmark asserts
        # bit-identical results; this gate pins the margin from eroding)
        "ycsb-a_read_reduction": ("min", 0.02),
        "ycsb-a_descent_remote_reads_per_op": COUNTER,
        "ycsb-a_leaf_direct_remote_reads_per_op": COUNTER,
        "ycsb-a_rt_skips": COUNTER,
        # the hotspot-shift cycle: a retrain must keep restoring accepted
        # probes after the stale-table collapse
        "hotspot_retrained_skips_per_op": ("min", 0.25),
    },
}


def _band_of(template, measured):
    kind = template[0]
    if kind == "rel":
        return {"value": measured, "rel_band": [template[1], template[2]]}
    if kind == "min":
        return {"min": template[1]}
    if kind == "exact":
        return {"value": measured, "exact": True}
    raise ValueError(f"unknown band template {template!r}")


def _evaluate(band, x):
    """-> (ok, expectation string)."""
    if band.get("exact"):
        v = band["value"]
        tol = 1e-9 * max(abs(v), 1.0)
        return abs(x - v) <= tol, f"== {v:g}"
    if "rel_band" in band:
        v, (lo, hi) = band["value"], band["rel_band"]
        return (lo * v <= x <= hi * v), f"[{lo * v:g}, {hi * v:g}]"
    if "min" in band:
        return x >= band["min"], f">= {band['min']:g}"
    raise ValueError(f"malformed band {band!r}")


def _delta(band, x):
    v = band.get("value")
    if not v:
        return "-"
    return f"{(x / v - 1.0) * 100.0:+.1f}%"


def check(results_doc, baselines_doc, *, out=print):
    """Validate one results file against the committed bands.

    Returns the number of failures; prints the full delta table either
    way so a green run still leaves a perf breadcrumb in the CI log.
    """
    mode = "quick" if results_doc.get("quick") else "full"
    results = results_doc["results"]
    bands = baselines_doc.get(mode)
    if bands is None:
        out(f"perf gate: no '{mode}' section in baselines — run "
            f"--update-baselines on a {mode} results file first")
        return 1

    failures = 0
    table = []
    for module, metrics in sorted(bands.items()):
        mod = results.get(module)
        if mod is None:
            table.append((module, "(module)", "-", "-", "-", "MISSING"))
            failures += 1
            continue
        if "error" in mod:
            table.append((module, "(module)", "-", "-", "-", "ERROR"))
            failures += 1
            continue
        summary = mod.get("summary", {})
        for metric, band in sorted(metrics.items()):
            if metric not in summary:
                table.append((module, metric, "-", "-", "-", "MISSING"))
                failures += 1
                continue
            x = float(summary[metric])
            ok, expect = _evaluate(band, x)
            table.append((
                module, metric, f"{x:g}", expect, _delta(band, x),
                "ok" if ok else "FAIL",
            ))
            failures += 0 if ok else 1

    widths = [max(len(r[i]) for r in table + [_HEADER]) for i in range(6)]
    for row in [_HEADER] + table:
        out("  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip())
    if failures:
        out(f"perf gate: FAIL — {failures} gated metric(s) out of band "
            f"(mode={mode}); if intentional, refresh via --update-baselines "
            f"and commit benchmarks/baselines.json")
    else:
        out(f"perf gate: OK — {sum(len(m) for m in bands.values())} gated "
            f"metric(s) in band (mode={mode})")
    return failures


_HEADER = ("module", "metric", "measured", "band", "delta", "status")


def update_baselines(results_doc, baselines_path):
    mode = "quick" if results_doc.get("quick") else "full"
    results = results_doc["results"]
    path = pathlib.Path(baselines_path)
    doc = json.loads(path.read_text()) if path.is_file() else {}
    section = {}
    missing = []
    for module, metrics in GATES.items():
        mod = results.get(module)
        if mod is None or "error" in mod:
            missing.append(module)
            continue
        summary = mod.get("summary", {})
        section[module] = {}
        for metric, template in metrics.items():
            if metric not in summary:
                missing.append(f"{module}.{metric}")
                continue
            section[module][metric] = _band_of(
                template, float(summary[metric])
            )
    if missing:
        print(f"perf gate: cannot update baselines — results file lacks: "
              f"{missing}")
        return 1
    doc[mode] = section
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print(f"perf gate: wrote {path} ({mode} section, "
          f"{sum(len(m) for m in section.values())} metrics)")
    return 0


def self_test(results_doc, baselines_doc):
    """Prove the gate trips: the pristine results must pass, and a copy
    perturbed below each band kind must fail."""
    sink = []
    if check(results_doc, baselines_doc, out=sink.append):
        print("\n".join(sink))
        print("perf gate self-test: FAIL — pristine results do not pass; "
              "refresh baselines first")
        return 1

    mode = "quick" if results_doc.get("quick") else "full"
    tripped, tested = 0, 0
    for module, metrics in baselines_doc[mode].items():
        for metric, band in metrics.items():
            broken = copy.deepcopy(results_doc)
            summary = broken["results"][module]["summary"]
            if "rel_band" in band:
                summary[metric] = band["value"] * band["rel_band"][0] * 0.5
            elif "min" in band:
                summary[metric] = band["min"] * 0.5
            else:  # exact
                summary[metric] = band["value"] + 1.0
            tested += 1
            if check(broken, baselines_doc, out=lambda _s: None):
                tripped += 1
            else:
                print(f"perf gate self-test: {module}.{metric} perturbed "
                      f"out of band but the gate did NOT trip")
    if tripped != tested:
        print(f"perf gate self-test: FAIL — only {tripped}/{tested} "
              f"perturbations tripped the gate")
        return 1
    print(f"perf gate self-test: OK — pristine results pass and all "
          f"{tested} single-metric perturbations trip the gate")
    return 0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="perf-regression gate over bench_results.json")
    ap.add_argument("results", help="bench_results.json from benchmarks.run")
    ap.add_argument("--baselines", default=str(DEFAULT_BASELINES))
    ap.add_argument("--update-baselines", action="store_true",
                    help="rewrite the baselines section for this results "
                         "file's mode from its measured values")
    ap.add_argument("--self-test", action="store_true",
                    help="dry-run: assert the gate passes on these results "
                         "and demonstrably fails on perturbed copies")
    args = ap.parse_args(argv)

    with open(args.results) as f:
        results_doc = json.load(f)
    if args.update_baselines:
        sys.exit(update_baselines(results_doc, args.baselines))
    with open(args.baselines) as f:
        baselines_doc = json.load(f)
    if args.self_test:
        sys.exit(self_test(results_doc, baselines_doc))
    sys.exit(1 if check(results_doc, baselines_doc) else 0)


if __name__ == "__main__":
    main()
