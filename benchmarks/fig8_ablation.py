"""Fig. 8: ablation — baseline RDMA tree -> +logical partitioning ->
+caching -> +opportunistic offloading, write-intensive, 1% cache (31MB).

Paper claims: partitioning 2.4x at 2 threads; +caching 21.2x (skew) / 6.9x
(uniform); +offloading +55% (skew) / +34% (uniform)."""

from benchmarks.common import HEADER, run_one, seed_kwargs

STAGES = [
    ("naive", "baseline"),
    ("dex-partition", "+partitioning"),
    ("dex-cache", "+caching"),
    ("dex", "+offloading"),
]


def run(quick: bool = False, seed: "int | None" = None):
    skw = seed_kwargs(seed)
    rows = [HEADER]
    summary = {}
    for theta, label in ([(0.99, "skewed")] if quick else
                         [(0.99, "skewed"), (0.0, "uniform")]):
        prev = None
        for system, stage in STAGES:
            r = run_one(
                system, "write-intensive", cache_ratio=0.01, theta=theta,
                threads=144, **skw,
            )
            rows.append(r.row())
            x = r.report.mops()
            if prev is not None:
                summary[f"{label}:{stage}"] = x / max(prev, 1e-9)
            prev = x
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k}: {v:.2f}x over previous stage")


if __name__ == "__main__":
    main()
