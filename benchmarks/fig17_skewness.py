"""Fig. 17: workload-skew sensitivity (zipf theta sweep).

Paper claims: DEX improves with skew (hot paths cache better); Sherman's
write-intensive throughput collapses at theta=0.99 (RDMA lock retries on hot
leaves), DEX does not (local locks only)."""

from benchmarks.common import HEADER, run_one, seed_kwargs

THETAS = [0.0, 0.5, 0.8, 0.99]


def run(quick: bool = False, seed: "int | None" = None):
    skw = seed_kwargs(seed)
    rows = [HEADER]
    summary = {}
    thetas = THETAS[::3] if quick else THETAS
    for theta in thetas:
        for system in ["dex", "sherman"]:
            for wl in ["read-intensive", "write-intensive"]:
                r = run_one(system, wl, theta=theta, n_ops=20_000,
                            **skw)
                rows.append(
                    f"{system}@t{theta}," + r.row().split(",", 1)[1]
                )
                summary[f"{system}:{wl}@theta={theta}"] = r.report.mops()
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k}: {v:.2f} Mops")


if __name__ == "__main__":
    main()
