"""Fig. 10 companion (mesh): *live* logical repartitioning under a shifting
zipfian hotspot on the mesh plane (Plane B).

The event-simulator benchmark (benchmarks/fig10_repartition.py) prices a
single offline repartition.  This one closes the loop the paper describes in
§4: a spatially localized zipfian workload (``ycsb.generate(...,
hotspot=...)``) hammers one compute partition, the routing buckets load-shed
the overflow (``STAT_DROPS``), and the :class:`RepartitionController`
accumulates the per-partition served load from the ops' own stat counters,
rebalances the boundary table between batches, and installs it — boundary
metadata swap plus version-table invalidation of moved nodes, no data
movement.  Mid-run the hotspot jumps to the other end of the key space and
the controller must chase it.

The same trace runs twice — static partitions vs. live controller — and the
controller run must *strictly* reduce total drops.  Results stay
bit-identical to a ``HostBTree`` replay (lookups over every key, scans, and
the update stream), and each install is cross-validated against
``Simulator.repartition`` cost on the same trace (fraction of the key space
moved must agree; the simulator additionally prices the dirty-page flush).

Run with ``PYTHONPATH=src python benchmarks/fig10_mesh_repartition.py
[--quick]`` or via the suite: ``PYTHONPATH=src python -m benchmarks.run
--only fig10meshrep``.  Needs the forced-8-device mesh (4 route x 2 memory);
with fewer devices it degrades to fewer partitions and skips the
drop-reduction assertion when partitioning is impossible.
"""

from __future__ import annotations

import os
import pathlib
import sys
import time

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import baselines  # noqa: E402
from repro.core import dex as dex_mod  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core import scan as scan_mod  # noqa: E402
from repro.core import write as write_mod  # noqa: E402
from repro.core.nodes import KEY_MAX  # noqa: E402
from repro.core.partition import LogicalPartitions  # noqa: E402
from repro.core.repartition import (  # noqa: E402
    RepartitionConfig,
    RepartitionController,
)
from repro.compat import make_mesh_compat  # noqa: E402
from repro.core.sim import HostBTree, Simulator  # noqa: E402
from repro.data import ycsb  # noqa: E402
from repro.obs import drift  # noqa: E402

from benchmarks import common  # noqa: E402
from benchmarks.common import (  # noqa: E402
    lookup_with_retries,
    scan_with_retries,
)

BATCH = 1024
MAX_SCAN = 32
UPDATE_XOR = 0x5A5A
SCAN_EVERY = 4          # every 4th batch also runs a scan batch
HOT_BEFORE, HOT_AFTER = 0.2, 0.8


def _topology():
    n_dev = len(jax.devices())
    if n_dev >= 8:
        return (4, 2), 4, 2
    if n_dev >= 2:
        return (2, 1), 2, 1
    return (1, 1), 1, 1


def _make_trace(dataset, n_batches, seed):
    """Hotspot-shift trace: ycsb-a (50/50 lookup/update) with the zipfian
    centered at 20% of the key space, jumping to 80% halfway through."""
    half = n_batches // 2
    w1 = ycsb.generate("ycsb-a", dataset, half * BATCH, theta=0.99,
                       seed=seed, hotspot=HOT_BEFORE)
    w2 = ycsb.generate("ycsb-a", dataset, (n_batches - half) * BATCH,
                       theta=0.99, seed=seed + 1, hotspot=HOT_AFTER)
    ops = np.concatenate([w1.ops, w2.ops])
    keys = np.concatenate([w1.keys, w2.keys])
    return ops, keys, half


def _run_trace(dataset, ops, keys, shift_batch, *, adaptive):
    """One full pass over the trace; returns metrics + final state/host."""
    vals = dataset * 7
    shape, n_route, n_memory = _topology()
    pool, meta = pool_mod.build_pool(dataset, vals, level_m=1, fill=0.7,
                                     n_shards=n_memory)
    host = HostBTree(dataset, vals, fill=0.7)
    mesh = make_mesh_compat(shape, ("data", "model"))
    parts = LogicalPartitions.equal_width(
        n_route, int(dataset.min()), int(dataset.max()) + 1
    )
    cfg = dex_mod.DexMeshConfig(
        route_axes=("data",), memory_axis="model",
        n_route=n_route, n_memory=n_memory,
        cache_sets=512, cache_ways=4,
        policy="fetch",
        # tight enough that a partition absorbing > 2x its fair share of a
        # batch sheds load — the signal repartitioning must eliminate
        route_capacity_factor=2.0,
    )
    state = dex_mod.init_state(pool, meta, cfg, parts.boundaries)
    shardings = dex_mod.state_shardings(mesh, cfg)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
    sharding = NamedSharding(mesh, P(("data", "model")))
    lookup = jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh))
    update = jax.jit(write_mod.make_dex_update(meta, cfg, mesh))
    scan = jax.jit(scan_mod.make_dex_scan(meta, cfg, mesh, max_count=MAX_SCAN))

    ctl = None
    if adaptive:
        ctl = RepartitionController(
            parts, n_memory=n_memory,
            # decide every batch: the rebalance refines the spike-bearing
            # partition geometrically, so a hotspot shift needs ~3 quick
            # rounds to converge
            cfg=RepartitionConfig(
                imbalance_threshold=1.25, drop_frac=0.005,
                min_ops=BATCH, cooldown_batches=0,
            ),
        )

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    rng = np.random.default_rng(17)
    n_batches = ops.size // BATCH
    drops_series = []
    repart_batches = []
    tl = common.new_timeline(
        f"fig10meshrep_{'live' if adaptive else 'static'}",
        devices=len(jax.devices()), batch=BATCH, adaptive=adaptive,
    )
    tl.prime(state.stats)
    t_start = time.perf_counter()
    for b in range(n_batches):
        bo = ops[b * BATCH : (b + 1) * BATCH]
        bk = keys[b * BATCH : (b + 1) * BATCH]
        lk = np.where(bo == ycsb.OP_LOOKUP, bk, KEY_MAX)
        uk = np.where(bo == ycsb.OP_UPDATE, bk, KEY_MAX)
        uv = uk ^ (UPDATE_XOR + b)
        ob = tl.batch(f"b{b}")
        ob.__enter__()
        with ob.phase("lookup") as ph:
            state, found, got_v, shed_l = lookup(state, put(lk))
            ph.fence((state, found, got_v, shed_l))
        with ob.phase("update") as ph:
            state, ru = update(state, put(uk), put(uv))
            ph.fence((state, ru))
        ru = np.asarray(ru)
        # host mirror replays exactly what the mesh applied (shed update
        # lanes were refused by the bucket, so the mirror skips them too)
        upd_mask = (bo == ycsb.OP_UPDATE) & (ru == write_mod.STATUS_OK)
        for k in bk[upd_mask]:
            host.update(int(k), int(k) ^ (UPDATE_XOR + b))
        # spot-check completed lookups against the mirror (pre-update phase
        # ordering matches fig6_mesh_mixed)
        found = np.asarray(found)
        got_v = np.asarray(got_v)
        shed_l = np.asarray(shed_l)
        lanes = np.where((bo == ycsb.OP_LOOKUP) & ~shed_l)[0]
        if lanes.size:
            for i in rng.choice(lanes, size=min(8, lanes.size), replace=False):
                hv = host.get(int(bk[i]))
                assert bool(found[i]) == (hv is not None), (b, i)
        if b % SCAN_EVERY == 0:
            sk = bk[:BATCH].copy()            # scans over the same hot keys
            cnt = np.full(BATCH, MAX_SCAN, np.int64)
            with ob.phase("scan") as ph:
                state, _, _, _tk = scan(state, put(sk), put(cnt))
                ph.fence((state, _tk))
        if ctl is not None:
            ctl.observe(np.asarray(state.stats), bk,
                        demand=np.asarray(state.route_demand))
            state, report = ctl.maybe_repartition(state, meta, obs=ob)
            if report is not None:
                repart_batches.append((b, report))
        dstats = ob.counters(state.stats)
        ob.__exit__(None, None, None)
        drops_series.append(int(dstats.fleet["drops"]))
    jax.block_until_ready(state)
    dt = time.perf_counter() - t_start
    common.finish_timeline(tl)

    stats = np.asarray(state.stats).sum(axis=0)
    return {
        "state": state, "host": host, "meta": meta, "cfg": cfg,
        "mesh": mesh, "sharding": sharding, "lookup": lookup, "scan": scan,
        "n_route": n_route, "dt": dt,
        "drops_series": np.asarray(drops_series),
        "drops_total": int(stats[dex_mod.STAT_DROPS]),
        "ops_total": int(stats[dex_mod.STAT_OPS]),
        "repart_events": repart_batches,
        "shift_batch": shift_batch,
        "controller": ctl,
    }


def _validate_bit_identical(res, dataset, rng):
    """Post-trace: every key's lookup and a scan sweep must replay the host
    mirror bit-for-bit; shed lanes are retried (bounded), never compared."""
    host, lookup, scan = res["host"], res["lookup"], res["scan"]
    state = res["state"]
    put = lambda x: jax.device_put(jnp.asarray(x), res["sharding"])  # noqa: E731

    probe = dataset.copy()
    pad = (-probe.size) % BATCH
    probe = np.concatenate([probe, np.full(pad, KEY_MAX, np.int64)])
    exp_vals = np.array(
        [host.get(int(k)) if k != KEY_MAX else 0 for k in probe], np.int64
    )
    got_vals = np.zeros_like(exp_vals)
    got_found = np.zeros(probe.shape, bool)
    for b in range(probe.size // BATCH):
        sl = slice(b * BATCH, (b + 1) * BATCH)
        state, fnd, vls, done = lookup_with_retries(
            lookup, state, put, probe[sl], max_retries=8
        )
        assert done.all(), "lookup lanes still shed after bounded retries"
        got_found[sl] = fnd
        got_vals[sl] = vls
    real = probe != KEY_MAX
    assert got_found[real].all(), "post-repartition lookup lost keys"
    assert np.array_equal(got_vals[real], exp_vals[real]), (
        "post-repartition lookups diverge from HostBTree replay"
    )

    starts = rng.choice(dataset, size=256).astype(np.int64)
    starts = np.concatenate([starts, np.full(BATCH - 256, KEY_MAX, np.int64)])
    cnts = np.full(BATCH, MAX_SCAN, np.int64)
    state, out_k, out_v, _taken, done = scan_with_retries(
        scan, state, put, starts, cnts, max_count=MAX_SCAN, max_retries=8
    )
    assert done.all(), "scan lanes still shed after bounded retries"
    for i in range(256):
        expect = [k for _, ks in host.scan(int(starts[i]), MAX_SCAN)
                  for k in ks][:MAX_SCAN]
        got = out_k[i][out_k[i] != KEY_MAX].tolist()
        assert got == expect, f"post-repartition scan keys diverge at {i}"
        for j, k in enumerate(expect):
            assert int(out_v[i, j]) == host.get(int(k)), (
                f"post-repartition scan value diverges at {i},{j}"
            )
    return state


def _simulator_cross_check(dataset, ops, keys, res):
    """Plane A on the same trace: replay the op stream, apply the very same
    boundary tables at the same batch indices, and check both planes agree
    on the fraction of the *dataset* whose owner each install moved (the
    simulator additionally prices the dirty-page flush).

    The comparison is over dataset keys under each plane's actual tables
    (mesh: the requested boundaries; sim: its leaf-fence-snapped version)
    rather than the hull-sampled ``assignment_diff`` — once the controller
    converges, boundaries sit closer together than a leaf span and the
    hull-sampled fractions measure different windows entirely."""
    tree = HostBTree(dataset, dataset * 7, fill=0.7, level_m=3,
                     n_mem_servers=4)
    sim = Simulator(tree, baselines.dex(n_compute=res["n_route"]), seed=7)
    cursor = 0
    rows = []
    n_checked = 0
    for b, report in res["repart_events"]:
        upto = (b + 1) * BATCH
        sim.run(ops[cursor:upto], keys[cursor:upto])
        cursor = upto
        sim_prev = sim.partitions
        cost = sim.repartition(LogicalPartitions(report.new_boundaries))
        rows.append((b, report, cost))
        old = LogicalPartitions(report.old_boundaries)
        new = LogicalPartitions(report.new_boundaries)
        # the check only applies while the simulator's snapped tables still
        # express the same partition count: once the controller converges,
        # adjacent boundaries can fall inside one leaf and the snap merges
        # them, shifting every higher owner id
        if (sim_prev.num_partitions == old.num_partitions
                and sim.partitions.num_partitions == new.num_partitions):
            mesh_frac = float(
                np.mean(old.owner_of(dataset) != new.owner_of(dataset))
            )
            sim_frac = float(
                np.mean(sim_prev.owner_of(dataset)
                        != sim.partitions.owner_of(dataset))
            )
            # fence snapping shifts each boundary by at most one leaf span
            drift.assert_plane_agreement(
                {"moved_fraction": mesh_frac},
                {"moved_fraction": sim_frac},
                {"moved_fraction": drift.absolute(0.10)},
                label=f"fig10meshrep install@batch{b}",
            )
            n_checked += 1
    if cursor < ops.size:
        sim.run(ops[cursor:], keys[cursor:])
    assert n_checked > 0, "no install was cross-checked against Plane A"
    return rows


def run(quick: bool = False, seed: "int | None" = None):
    s = 0 if seed is None else int(seed)
    n_keys = 20_000 if quick else 50_000
    n_batches = 12 if quick else 20
    rng = np.random.default_rng(s + 9)
    dataset = ycsb.make_dataset(n_keys, seed=s)
    ops, keys, shift_batch = _make_trace(dataset, n_batches, seed=s + 21)

    static = _run_trace(dataset, ops, keys, shift_batch, adaptive=False)
    live = _run_trace(dataset, ops, keys, shift_batch, adaptive=True)

    _validate_bit_identical(live, dataset, rng)
    sim_rows = _simulator_cross_check(dataset, ops, keys, live)

    sh = shift_batch
    rows = ["mode,metric,value"]
    for name, r in (("static", static), ("live", live)):
        rows += [
            f"{name},ops_per_s,{r['ops_total'] / r['dt']:.1f}",
            f"{name},drops_total,{r['drops_total']}",
            f"{name},drops_before_shift,{int(r['drops_series'][:sh].sum())}",
            f"{name},drops_after_shift,{int(r['drops_series'][sh:].sum())}",
        ]
    for b, report in live["repart_events"]:
        rows.append(
            f"live,repartition@batch{b},imbalance={report.imbalance:.2f};"
            f"moved={report.fraction_keyspace_moved:.3f};"
            f"invalidated={report.nodes_invalidated};"
            f"shared={report.shared_nodes_before}->{report.shared_nodes_after}"
        )
    for b, _report, cost in sim_rows:
        rows.append(
            f"sim,repartition@batch{b},"
            f"flush_pages={cost['dirty_pages_flushed']:.0f};"
            f"flush_s={cost['flush_seconds_single_thread']:.4f};"
            f"moved={cost['fraction_keyspace_moved']:.3f}"
        )

    summary = {
        "static_drops": float(static["drops_total"]),
        "live_drops": float(live["drops_total"]),
        "n_repartitions": float(len(live["repart_events"])),
        "live_ops_per_s": live["ops_total"] / live["dt"],
    }
    if live["n_route"] >= 2:
        assert live["repart_events"], "controller never repartitioned"
        assert live["drops_total"] < static["drops_total"], (
            f"live repartitioning must strictly reduce drops: "
            f"{live['drops_total']} vs static {static['drops_total']}"
        )
    return rows, summary


def main():
    quick = "--quick" in sys.argv
    rows, summary = run(quick=quick)
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k} = {v:.2f}")


if __name__ == "__main__":
    main()
