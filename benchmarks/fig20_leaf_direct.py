"""Leaf-direct routing (core/route_table.py + DESIGN.md §13): remote reads
per op with and without the learned route table, end-to-end on the
forced-8-device mesh.

DEX's central claim is that fewer remote accesses win on disaggregated
memory (paper §1).  The leaf-direct route table resolves a key's leaf
compute-side (Outback-style, PAPERS.md) and probes it under the leaf
version fence, skipping the within-subtree inner descent when the fence
accepts.  This benchmark runs three arms over the SAME trace per mix:

  * ``descent``     — ``route_table_slots=0``: the verbatim pre-route-table
    engine program (statically pruned, bit-identical to the seed engine);
  * ``leaf-direct`` — a trained table, retrained host-side between batches
    (training is a between-batch host step, like repartition decisions);
  * ``poisoned``    — the same table with every entry's version stamp
    bumped (``route_table.poison_route_table``): the fence must reject
    every guess, so results AND remote-read counts must be bit-identical
    to the descent arm — correctness never depends on prediction quality.

Asserted per mix (YCSB-A/B/E):

  * all three arms' per-lane results are bit-identical to each other and
    validated against the phased ``HostBTree`` replay;
  * the leaf-direct arm books ``rt_skips`` > 0 and strictly fewer remote
    reads per op than the descent arm on YCSB-A (<= on B/E — scans never
    consult the table, so E's reduction rides on its 5% inserts);
  * the poisoned arm books only ``rt_mispredicts`` (zero skips) and reads
    exactly as much as the descent arm.

Cross-plane: the ``Simulator`` (``SimConfig.route_table_slots``) prices the
identical YCSB-A trace with the same train-between-batches schedule; the
``remote_reads_per_op`` derived metric (obs/registry.py) must agree within
the drift band for BOTH arms, and the sim must reproduce the reduction.

Hotspot shift: a localized YCSB-B hotspot trains the table into the hot
partition's leaves (``route_table_slots`` below the leaf count forces the
demand-driven keep), then the hotspot jumps to the other end of the key
space.  The stale table mispredicts (bounds reject — skips collapse);
after ``DexState.route_demand`` accumulates the new skew, retraining
restores the skip rate.  No correctness is lost at any point in between.

Run with ``PYTHONPATH=src python benchmarks/fig20_leaf_direct.py
[--quick]`` or via the suite: ``python -m benchmarks.run --only
fig20leafdirect``.
"""

from __future__ import annotations

import os
import pathlib
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import dex as dex_mod  # noqa: E402
from repro.core import engine as engine_mod  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core import route_table  # noqa: E402
from repro.core import smo as smo_mod  # noqa: E402
from repro.core import write as write_mod  # noqa: E402
from repro.core.nodes import KEY_MAX, KEY_MIN  # noqa: E402
from repro.compat import make_mesh_compat  # noqa: E402
from repro.core.sim import HostBTree, SimConfig, Simulator  # noqa: E402
from repro.data import ycsb  # noqa: E402

from repro.obs import drift, registry  # noqa: E402
from benchmarks import common  # noqa: E402
from benchmarks.common import engine_with_retries  # noqa: E402

BATCH = 1024          # full-mode batch width (quick mode halves it)
MC = 32               # scan max_count (E-mix scan lengths draw from [1, 24])
SCAN_LEN = 24
UPDATE_XOR = 0x5A5A
MAX_RETRIES = 4
#: small direct-mapped-ish cache (sets x 4 ways) so leaf churn keeps
#: evicting the inner rows: the descent arm pays recurring inner-level
#: fetches that the leaf-direct arm's accepted probes never issue.  Leaf
#: admission runs at 100% for the same reason (churn, not retention).
CACHE_SETS = 32
P_ADMIT_LEAF_PCT = 100

#: mixes and the opcode sets their engines need (scan lanes never consult
#: the route table; E's reduction rides on its inserts)
MIXES = (
    ("ycsb-a", ("lookup", "update")),
    ("ycsb-b", ("lookup", "update")),
    ("ycsb-e", ("insert", "scan")),
)


def _mesh_setup(dataset, *, rt_slots=0):
    vals = dataset * 7
    pool, meta = pool_mod.build_pool(dataset, vals, level_m=1, fill=0.7,
                                     n_shards=4)
    if len(jax.devices()) >= 8:
        shape, n_route, n_memory = (2, 4), 2, 4
        mid = int(dataset[dataset.size // 2])
        bounds = np.array([KEY_MIN, mid, KEY_MAX], dtype=np.int64)
    else:
        shape, n_route, n_memory = (1, 1), 1, 1
        bounds = np.array([KEY_MIN, KEY_MAX], dtype=np.int64)
    mesh = make_mesh_compat(shape, ("data", "model"))
    cfg = dex_mod.DexMeshConfig(
        route_axes=("data",), memory_axis="model",
        n_route=n_route, n_memory=n_memory,
        cache_sets=CACHE_SETS, cache_ways=4,
        policy="fetch",
        p_admit_leaf_pct=P_ADMIT_LEAF_PCT,
        route_capacity_factor=float(max(2, n_memory)),
        route_table_slots=rt_slots,
    )
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state,
        dex_mod.state_shardings(mesh, cfg),
    )
    sharding = NamedSharding(mesh, P(("data", "model")))
    return meta, mesh, cfg, bounds, state, sharding


def _phased_replay(host, rng, opc, kk, vv, found, vals, status, sk, tk,
                   done):
    """Validate one engine batch against the phased sequential replay
    (reads see the pre-batch index, then updates, then inserts); returns
    the insert lanes shed with STATUS_SPLIT for the SMO ladder."""
    for i in np.where((opc == ycsb.OP_LOOKUP) & done)[0]:
        hv = host.get(int(kk[i]))
        assert bool(found[i]) == (hv is not None), int(kk[i])
        if hv is not None:
            assert int(vals[i]) == hv, int(kk[i])
    sc_ok = np.where((opc == ycsb.OP_SCAN) & done)[0]
    for i in rng.choice(sc_ok, size=min(8, sc_ok.size), replace=False):
        exp = [k for _, ks in host.scan(int(kk[i]), int(vv[i]))
               for k in ks][: int(vv[i])]
        got = sk[i][sk[i] != KEY_MAX].tolist()
        assert got == exp, (int(kk[i]), got[:4], exp[:4])
        assert int(tk[i]) == len(exp)
    for i in np.where((opc == ycsb.OP_UPDATE) & done)[0]:
        applied = host.update(int(kk[i]), int(vv[i]))
        assert (status[i] == write_mod.STATUS_OK) == applied, int(kk[i])
    ins = (opc == ycsb.OP_INSERT) & done
    for i in np.where(ins)[0]:
        if status[i] == write_mod.STATUS_OK:
            host.insert(int(kk[i]), int(vv[i]))
    return ins & (status == write_mod.STATUS_SPLIT)


def _run_arm(name, ops_set, dataset, wl, n_batches, n_warm, rng, batch, *,
             rt_slots=0, poison=False, tl=None):
    """One engine arm over the shared trace.  ``rt_slots`` > 0 trains the
    route table after warmup and retrains host-side before every measured
    batch (the write-heavy mixes version-fence entries out within one
    batch; retraining between batches is the table's operating model).
    ``poison`` re-poisons after every (re)train, so the fence rejects every
    guess for the whole measured window."""
    meta, mesh, cfg, bounds, state, sharding = _mesh_setup(
        dataset, rt_slots=rt_slots)
    host = HostBTree(dataset, dataset * 7, fill=0.7)
    eng = jax.jit(engine_mod.make_dex_engine(meta, cfg, mesh, ops=ops_set,
                                             max_count=MC))
    smo = jax.jit(smo_mod.make_dex_smo(meta, cfg, mesh))

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    def retrain(state):
        if rt_slots:
            state = route_table.train_route_table(state, meta, mesh=mesh)
            if poison:
                state = route_table.poison_route_table(state)
        return state

    outs = []
    stats_warm = None
    n_entries = 0
    for b in range(n_warm + n_batches):
        if b >= n_warm:
            # host-side between-batch (re)train — same cadence both planes
            state = retrain(state)
        if b == n_warm:
            jax.block_until_ready(state.stats)
            stats_warm = np.asarray(state.stats).sum(axis=0)
            if rt_slots:
                n_entries = int(
                    (np.asarray(state.rt_ver) >= 0).sum())
            if tl is not None:
                tl.meta["leaf_direct"] = {
                    "slots": rt_slots, "entries": n_entries,
                    "poisoned": bool(poison),
                }
                tl.prime(state.stats)
        opc, kk, vv = ycsb.engine_lanes(
            wl, b * batch, (b + 1) * batch, update_xor=UPDATE_XOR
        )
        ob = None
        if tl is not None and b >= n_warm:
            ob = tl.batch(name)
            with ob:
                state, found, vals, status, sk, sv, tk, done = (
                    engine_with_retries(eng, state, put, opc, kk, vv,
                                        max_retries=MAX_RETRIES, obs=ob)
                )
                ob.counters(state.stats)
        else:
            state, found, vals, status, sk, sv, tk, done = (
                engine_with_retries(eng, state, put, opc, kk, vv,
                                    max_retries=MAX_RETRIES)
            )
        if b >= n_warm:
            outs.append((found, vals, status,
                         sk if sk is not None else np.zeros(0),
                         tk, done))
        shed = _phased_replay(host, rng, opc, kk, vv, found, vals, status,
                              sk, tk, done)
        if shed.any():
            state, meta2, info = smo_mod.settle_splits(
                state, meta, cfg, smo, host,
                np.where(shed, kk, KEY_MAX), np.where(shed, vv, 0), bounds,
                obs=ob,
            )
            if info["drained"]:
                meta = meta2
                state = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), state,
                    dex_mod.state_shardings(mesh, cfg),
                )
                eng = jax.jit(engine_mod.make_dex_engine(
                    meta, cfg, mesh, ops=ops_set, max_count=MC))
                smo = jax.jit(smo_mod.make_dex_smo(meta, cfg, mesh))
    jax.block_until_ready(state.stats)
    stats = np.asarray(state.stats).sum(axis=0) - stats_warm
    return dict(stats=stats, outs=outs, entries=n_entries, meta=meta,
                cfg=cfg)


def _assert_bit_identical(a, b, label):
    for i, (ta, tb) in enumerate(zip(a, b)):
        for arr_a, arr_b in zip(ta, tb):
            np.testing.assert_array_equal(
                arr_a, arr_b, err_msg=f"{label}: batch {i}")


def _sim_arm(dataset, wl, n_batches, n_warm, batch, cfg, meta, *,
             rt_slots=0, poison=False):
    """Plane A on the identical trace: same cache budget, same blocked
    placement, same train-between-batches schedule (``offloading`` stays
    off on both planes — the mesh arm runs ``policy="fetch"``)."""
    sim_tree = HostBTree(
        dataset, dataset * 7, fill=0.7, level_m=1,
        n_mem_servers=cfg.n_memory, placement="blocked",
        subtrees_per_server=meta.n_subtrees_padded // cfg.n_memory,
    )
    sim_cfg = SimConfig(
        name="dex-engine", n_compute=cfg.n_devices,
        n_mem_servers=cfg.n_memory, level_m=1,
        write_through=True, offloading=False,
        coherence_batch=batch, route_dispersion=cfg.n_memory,
        p_admit_leaf=cfg.p_admit_leaf_pct / 100.0,
        cache_bytes=cfg.cache_sets * cfg.cache_ways * 1024,
        route_table_slots=rt_slots,
    )
    sim = Simulator(sim_tree, sim_cfg, seed=3)
    sim.run(wl.ops[: n_warm * batch], wl.keys[: n_warm * batch])
    sim.reset_counters()
    for b in range(n_warm, n_warm + n_batches):
        if rt_slots:
            sim.train_route_table()
            if poison:
                sim.poison_route_table()
        sl = slice(b * batch, (b + 1) * batch)
        sim.run(wl.ops[sl], wl.keys[sl])
    return sim.totals()


def _run_hotspot(dataset, n_warm, batch, rng, *, slots, n_p1, n_stale,
                 n_fresh):
    """Hotspot-shift arm (YCSB-B, localized skew): the table is trained
    once into the phase-1 hot partition, the hotspot jumps, the stale
    table's skips collapse into bounds mispredicts, and a retrain off the
    accumulated ``route_demand`` restores them.  Returns per-phase per-op
    skip/mispredict rates; every batch is host-replay validated."""
    meta, mesh, cfg, _bounds, state, sharding = _mesh_setup(
        dataset, rt_slots=slots)
    host = HostBTree(dataset, dataset * 7, fill=0.7)
    eng = jax.jit(engine_mod.make_dex_engine(
        meta, cfg, mesh, ops=("lookup", "update"), max_count=1))

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    # scrambled warm loads both partitions' demand evenly; phase 1 then
    # tips it toward the low hotspot, so the demand-driven keep covers the
    # phase-1 hot leaves.  After the shift, n_stale batches are enough for
    # the cumulative demand to cross over to the other partition.
    n_p2 = n_stale + n_fresh
    wl_w = ycsb.generate("ycsb-b", dataset, n_warm * batch, theta=0.99,
                         seed=11)
    wl_1 = ycsb.generate("ycsb-b", dataset, (n_p1 + 1) * batch, theta=0.99,
                         seed=12, hotspot=0.15)
    wl_2 = ycsb.generate("ycsb-b", dataset, n_p2 * batch, theta=0.99,
                         seed=13, hotspot=0.85)
    wl = ycsb.Workload(
        ops=np.concatenate([wl_w.ops, wl_1.ops, wl_2.ops]),
        keys=np.concatenate([wl_w.keys, wl_1.keys, wl_2.keys]),
    )

    phases = {}

    def span(label, lo, hi, retrain_first=False):
        nonlocal state
        if retrain_first:
            state = route_table.train_route_table(state, meta, mesh=mesh)
        jax.block_until_ready(state.stats)
        before = np.asarray(state.stats).sum(axis=0)
        for b in range(lo, hi):
            opc, kk, vv = ycsb.engine_lanes(
                wl, b * batch, (b + 1) * batch, update_xor=UPDATE_XOR)
            state, found, vals, status, sk, sv, tk, done = (
                engine_with_retries(eng, state, put, opc, kk, vv,
                                    max_retries=MAX_RETRIES)
            )
            shed = _phased_replay(host, rng, opc, kk, vv, found, vals,
                                  status, sk, tk, done)
            assert not shed.any()
        jax.block_until_ready(state.stats)
        d = np.asarray(state.stats).sum(axis=0) - before
        ops = max(int(d[dex_mod.STAT_OPS]), 1)
        phases[label] = dict(
            ops=int(d[dex_mod.STAT_OPS]),
            skips_per_op=float(d[dex_mod.STAT_RT_SKIPS]) / ops,
            mispredicts_per_op=float(d[dex_mod.STAT_RT_MISPREDICTS]) / ops,
        )

    # warm (no table), then one demand-priming phase-1 batch before the
    # train so the keep targets the phase-1 hot partition
    span("warm", 0, n_warm + 1)
    span("phase1", n_warm + 1, n_warm + 1 + n_p1, retrain_first=True)
    b2 = n_warm + 1 + n_p1
    span("stale", b2, b2 + n_stale)
    span("retrained", b2 + n_stale, b2 + n_p2, retrain_first=True)
    n_leaves = route_table.leaf_ranges(state, meta)[0].size
    phases["n_leaves"] = int(n_leaves)
    phases["slots"] = int(slots)
    return phases


def run(quick: bool = False, seed: "int | None" = None):
    base_seed = 0 if seed is None else int(seed)
    n_keys = 30_000 if quick else 100_000
    n_batches = 3 if quick else 5
    n_warm = 2 if quick else 3
    batch = 512 if quick else BATCH
    rt_slots = 1024 if quick else 4096     # covers every leaf in the main arms
    rng = np.random.default_rng(base_seed + 5)
    dataset = ycsb.make_dataset(n_keys, seed=base_seed)
    eight = len(jax.devices()) >= 8
    rows = ["plane,workload,metric,value"]
    summary = {}

    sim_inputs = {}
    for name, ops_set in MIXES:
        wl = ycsb.generate(name, dataset, (n_warm + n_batches) * batch,
                           theta=0.99, seed=11, scan_len=SCAN_LEN,
                           scan_len_dist="uniform")
        de = _run_arm(name, ops_set, dataset, wl, n_batches, n_warm, rng,
                      batch, rt_slots=0)
        tl = common.new_timeline(f"fig20leafdirect_{name}",
                                 devices=len(jax.devices()), batch=batch)
        ld = _run_arm(name, ops_set, dataset, wl, n_batches, n_warm, rng,
                      batch, rt_slots=rt_slots, tl=tl)
        common.finish_timeline(tl)
        po = _run_arm(name, ops_set, dataset, wl, n_batches, n_warm, rng,
                      batch, rt_slots=rt_slots, poison=True)

        # equal correctness: all three arms are bit-identical, lane for
        # lane, on every measured batch (each already host-replay checked)
        _assert_bit_identical(de["outs"], ld["outs"], f"{name} leaf-direct")
        _assert_bit_identical(de["outs"], po["outs"], f"{name} poisoned")

        snap = {k: registry.snapshot(a["stats"][None, :])
                for k, a in (("descent", de), ("leaf_direct", ld),
                             ("poisoned", po))}
        for arm, s in snap.items():
            rows += [
                f"engine,{name},{arm}_remote_reads_per_op,"
                f"{s['remote_reads_per_op']:.4f}",
                f"engine,{name},{arm}_fetches,{s['fetches']}",
                f"engine,{name},{arm}_rt_skips,{s['rt_skips']}",
                f"engine,{name},{arm}_rt_mispredicts,{s['rt_mispredicts']}",
            ]
            summary[f"{name}_{arm}_remote_reads_per_op"] = (
                s["remote_reads_per_op"])
        summary[f"{name}_rt_skips"] = float(snap["leaf_direct"]["rt_skips"])
        summary[f"{name}_rt_mispredicts"] = float(
            snap["leaf_direct"]["rt_mispredicts"])
        summary[f"{name}_read_reduction"] = 1.0 - (
            snap["leaf_direct"]["remote_reads_per_op"]
            / max(snap["descent"]["remote_reads_per_op"], 1e-12))
        summary[f"{name}_rt_entries"] = float(ld["entries"])

        # descent-only arm: the statically-pruned program books no route-
        # table counters at all (any-device, any-size invariant)
        assert snap["descent"]["rt_skips"] == 0
        assert snap["descent"]["rt_mispredicts"] == 0
        if eight:
            assert ld["entries"] > 0, name
            # accepted probes skipped inner rounds; the fence rejected the
            # rest (write-heavy mixes fence entries out mid-batch)
            assert snap["leaf_direct"]["rt_skips"] > 0, name
            # the poisoned table is all mispredicts, zero skips, and reads
            # EXACTLY as much as descent-only: the fallback is the same
            # cached descent, cache-decision for cache-decision
            assert snap["poisoned"]["rt_skips"] == 0, name
            assert snap["poisoned"]["rt_mispredicts"] > 0, name
            assert snap["poisoned"]["fetches"] == snap["descent"]["fetches"], (
                name, snap["poisoned"]["fetches"], snap["descent"]["fetches"])
            # the paper's claim, per mix: strictly fewer remote reads per
            # op on the update-heavy A mix; never more on B/E (scans skip
            # the table, so E's margin is only its 5% insert lanes)
            if name == "ycsb-a":
                assert (snap["leaf_direct"]["remote_reads_per_op"]
                        < snap["descent"]["remote_reads_per_op"]), (
                    snap["leaf_direct"]["remote_reads_per_op"],
                    snap["descent"]["remote_reads_per_op"])
            else:
                assert (snap["leaf_direct"]["remote_reads_per_op"]
                        <= snap["descent"]["remote_reads_per_op"]), name
        if name == "ycsb-a":
            sim_inputs = dict(wl=wl, de=de, ld=ld, snap=snap)

    # ------------------------------------------------------------------
    # Plane A mirror on the YCSB-A trace: same trace, same cache budget,
    # same between-batch train schedule; remote_reads_per_op must agree
    # within the drift band for BOTH arms and reproduce the reduction
    # ------------------------------------------------------------------
    cfg, meta = sim_inputs["de"]["cfg"], sim_inputs["de"]["meta"]
    sim_de = _sim_arm(dataset, sim_inputs["wl"], n_batches, n_warm, batch,
                      cfg, meta, rt_slots=0)
    sim_ld = _sim_arm(dataset, sim_inputs["wl"], n_batches, n_warm, batch,
                      cfg, meta, rt_slots=rt_slots)
    sim_named = {k: registry.sim_view(t)
                 for k, t in (("descent", sim_de), ("leaf_direct", sim_ld))}
    for arm in ("descent", "leaf_direct"):
        s = sim_named[arm]
        s["accesses_per_op"] = (s["hits"] + s["fetches"]) / max(s["ops"], 1)
        rows.append(
            f"sim,ycsb-a,{arm}_remote_reads_per_op,"
            f"{s['remote_reads_per_op']:.4f}")
        summary[f"sim_{arm}_remote_reads_per_op"] = s["remote_reads_per_op"]
        summary[f"sim_{arm}_accesses_per_op"] = s["accesses_per_op"]
    summary["sim_access_reduction"] = 1.0 - (
        sim_named["leaf_direct"]["accesses_per_op"]
        / max(sim_named["descent"]["accesses_per_op"], 1e-12))
    # The sim's cooling-LRU keeps the handful of inner rows resident, so the
    # modeled saving shows up as *node accesses eliminated* (each rt_skip is
    # one within-subtree probe that never happens); it converts to remote
    # reads only under conflict churn, which the mesh's set-associative
    # cache exhibits and the strict mesh assert above pins.  Here: strictly
    # fewer accesses per op, and never more remote reads than descent.
    assert (sim_named["leaf_direct"]["accesses_per_op"]
            < sim_named["descent"]["accesses_per_op"]), sim_named
    assert (sim_named["leaf_direct"]["remote_reads_per_op"]
            <= sim_named["descent"]["remote_reads_per_op"] * 1.05), sim_named
    assert sim_ld.rt_skips > 0
    if eight:
        for arm, totals in (("descent", sim_de), ("leaf_direct", sim_ld)):
            drift.assert_plane_agreement(
                sim_inputs["snap"][arm], totals,
                {"remote_reads_per_op": drift.ratio(0.5, 2.0),
                 "rt_skips": drift.ratio(0.25, 4.0, min_count=64)},
                label=f"fig20leafdirect ycsb-a {arm}",
            )

    # ------------------------------------------------------------------
    # Hotspot shift: stale table -> bounds mispredicts, retrain recovers
    # ------------------------------------------------------------------
    hs = _run_hotspot(
        dataset, n_warm, batch, rng,
        slots=256 if quick else 768,
        n_p1=2, n_stale=4 if quick else 5, n_fresh=2,
    )
    for ph in ("phase1", "stale", "retrained"):
        rows += [
            f"engine,hotspot,{ph}_skips_per_op,"
            f"{hs[ph]['skips_per_op']:.4f}",
            f"engine,hotspot,{ph}_mispredicts_per_op,"
            f"{hs[ph]['mispredicts_per_op']:.4f}",
        ]
        summary[f"hotspot_{ph}_skips_per_op"] = hs[ph]["skips_per_op"]
        summary[f"hotspot_{ph}_mispredicts_per_op"] = (
            hs[ph]["mispredicts_per_op"])
    if eight:
        # the keep was forced to choose (slots < live leaves), the fresh
        # table served phase 1, the shift broke it, the retrain fixed it
        assert hs["slots"] < hs["n_leaves"], hs
        assert hs["phase1"]["skips_per_op"] > 0.5, hs
        assert (hs["stale"]["skips_per_op"]
                < 0.5 * hs["phase1"]["skips_per_op"]), hs
        assert (hs["stale"]["mispredicts_per_op"]
                > hs["phase1"]["mispredicts_per_op"]), hs
        assert (hs["retrained"]["skips_per_op"]
                > 2.0 * hs["stale"]["skips_per_op"]), hs
        assert (hs["retrained"]["mispredicts_per_op"]
                < hs["stale"]["mispredicts_per_op"]), hs
    return rows, summary


def main():
    quick = "--quick" in sys.argv
    rows, summary = run(quick=quick)
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k} = {v}")


if __name__ == "__main__":
    main()
