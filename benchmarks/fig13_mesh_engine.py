"""Unified mixed-op engine (core/engine.py) vs the per-op-type
split-program baseline, end-to-end on the forced-8-device mesh.

Part 1 — *one communication plan for mixed batches*.  YCSB-A/B/E-mix
traces (data/ycsb.py, one interleaved stream with opcodes via
``ycsb.engine_lanes``) run through (a) the unified engine — one route
round, one shared version-checked cached descent, one fused tagged
request/response ``all_to_all`` pair — and (b) the pre-engine baseline:
one masked single-opcode program per op type, each paying its own route
round, descent and write/offload round.  Asserted per mix:

  * the engine's traced program holds exactly ONE route round
    (``route_exchange`` forward+reverse) and ONE fused pair, and strictly
    fewer ``all_to_all`` collectives than the split programs combined
    (``routing.trace_collective_counts``);
  * engine results are bit-identical to a phased ``HostBTree`` replay
    (reads see the pre-batch index, then updates, then inserts);
  * engine throughput on completed ops is no worse than the split path.

Part 2 — *per-group cost-aware offloading*.  A localized-hotspot YCSB-A
trace warms one memory column's per-(column, level) miss EMA under a
forced-fetch engine, then switches to ``policy="auto"``: the warm column
must keep fetching while cold columns offload *within the same batch*
(``STAT_OFFLOAD_GROUPS`` / ``STAT_FETCH_GROUPS`` both move in one batch),
and the mesh's per-group counts are cross-validated against the
``Simulator`` running the identical trace with ``SimConfig.group_offload``
(same byte-cost rule, same windowing, blocked subtree placement).

Part 3 — *continuous-service pipelining*.  The same YCSB-A trace streams
through ``make_dex_engine(..., pipeline=True)`` (prologue / steady state /
drain, results delivered one batch behind the pushes) and through the
batch-synchronous engine.  Asserted:

  * every batch of both services is validated lane-for-lane against the
    phased ``HostBTree`` replay, and the pipelined results are
    bit-identical to the synchronous ones (version checks + the
    conservative same-leaf conflict stall make reads overlapping writes
    safe);
  * one pipelined step issues exactly the synchronous program's
    collectives (pipelining adds NO communication), with the fused
    write round sitting in the ``pipe/back`` half — under the NEXT
    batch's descent;
  * the overlap-window stall counter (``STAT_PIPE_STALLS``) moves on the
    mesh and agrees with the ``Simulator`` pricing the identical trace
    with ``SimConfig.pipeline_overlap`` (forced two-sided re-resolution
    of descents into the previous window's written leaves);
  * sustained throughput ≥ 1.15x batch-synchronous in the priced plane
    (core/cost_model.py): hiding the write-back round drops it from the
    per-op critical path while the stall cost is charged.  Wall-clock on
    the emulated mesh is recorded but not gated — the 8 "devices"
    time-share host cores, so overlap cannot shorten wall time here.

Run with ``PYTHONPATH=src python benchmarks/fig13_mesh_engine.py
[--quick]`` or via the suite: ``python -m benchmarks.run --only
fig13engine``.
"""

from __future__ import annotations

import os
import pathlib
import sys
import time

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import cost_model  # noqa: E402
from repro.core import dex as dex_mod  # noqa: E402
from repro.core import engine as engine_mod  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core import routing  # noqa: E402
from repro.core import scan as scan_mod  # noqa: E402
from repro.core import smo as smo_mod  # noqa: E402
from repro.core import write as write_mod  # noqa: E402
from repro.core.nodes import KEY_MAX, KEY_MIN  # noqa: E402
from repro.compat import make_mesh_compat  # noqa: E402
from repro.core.sim import HostBTree, SimConfig, Simulator  # noqa: E402
from repro.data import ycsb  # noqa: E402

from repro.obs import drift, registry  # noqa: E402
from benchmarks import common  # noqa: E402
from benchmarks.common import (  # noqa: E402
    engine_with_retries,
    lookup_with_retries,
    scan_with_retries,
    timed_batch,
    write_with_retries,
)

BATCH = 1024          # full-mode batch width (quick mode halves it; the
#                       simulator's coherence window always matches)
MC = 32              # scan max_count (E-mix scan lengths draw from [1, 24])
SCAN_LEN = 24
UPDATE_XOR = 0x5A5A
MAX_RETRIES = 4

#: part-1 mixes and the opcode sets their engines need
MIXES = (
    ("ycsb-a", ("lookup", "update", "insert")),
    ("ycsb-b", ("lookup", "update", "insert")),
    ("ycsb-e", ("insert", "scan")),
)


def _mesh_setup(dataset, *, policy="fetch", cache_sets=512, ema_decay=0.98,
                p_admit_leaf_pct=10):
    vals = dataset * 7
    pool, meta = pool_mod.build_pool(dataset, vals, level_m=1, fill=0.7,
                                     n_shards=4)
    if len(jax.devices()) >= 8:
        shape, n_route, n_memory = (2, 4), 2, 4
        mid = int(dataset[dataset.size // 2])
        bounds = np.array([KEY_MIN, mid, KEY_MAX], dtype=np.int64)
    else:
        shape, n_route, n_memory = (1, 1), 1, 1
        bounds = np.array([KEY_MIN, KEY_MAX], dtype=np.int64)
    mesh = make_mesh_compat(shape, ("data", "model"))
    cfg = dex_mod.DexMeshConfig(
        route_axes=("data",), memory_axis="model",
        n_route=n_route, n_memory=n_memory,
        cache_sets=cache_sets, cache_ways=4,
        policy=policy, ema_decay=ema_decay,
        p_admit_leaf_pct=p_admit_leaf_pct,
        route_capacity_factor=float(max(2, n_memory)),
    )
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state,
        dex_mod.state_shardings(mesh, cfg),
    )
    sharding = NamedSharding(mesh, P(("data", "model")))
    return pool, meta, mesh, cfg, bounds, state, sharding


def _phased_host_replay(host, rng, opc, kk, vv, found, vals, status,
                        sk, sv, tk, done):
    """Validate one engine batch against the phased sequential replay:
    reads against the pre-batch host, then updates, then inserts.  Returns
    the insert lanes shed with STATUS_SPLIT (for the SMO ladder)."""
    lk_ok = np.where((opc == ycsb.OP_LOOKUP) & done)[0]
    for i in rng.choice(lk_ok, size=min(24, lk_ok.size), replace=False):
        hv = host.get(int(kk[i]))
        assert bool(found[i]) == (hv is not None), int(kk[i])
        if hv is not None:
            assert int(vals[i]) == hv, int(kk[i])
    sc_ok = np.where((opc == ycsb.OP_SCAN) & done)[0]
    for i in rng.choice(sc_ok, size=min(8, sc_ok.size), replace=False):
        exp = [k for _, ks in host.scan(int(kk[i]), int(vv[i]))
               for k in ks][: int(vv[i])]
        got = sk[i][sk[i] != KEY_MAX].tolist()
        assert got == exp, (int(kk[i]), got[:4], exp[:4])
        assert int(tk[i]) == len(exp)
    upd = (opc == ycsb.OP_UPDATE) & done
    for i in np.where(upd)[0]:
        applied = host.update(int(kk[i]), int(vv[i]))
        assert (status[i] == write_mod.STATUS_OK) == applied, int(kk[i])
    ins = (opc == ycsb.OP_INSERT) & done
    for i in np.where(ins)[0]:
        if status[i] == write_mod.STATUS_OK:
            host.insert(int(kk[i]), int(vv[i]))
    return ins & (status == write_mod.STATUS_SPLIT)


def _run_engine_path(name, ops_set, dataset, n_batches, n_warm, rng,
                     batch, tl=None):
    """Drive the mixed trace through the unified engine, with host-replay
    validation and the SMO settle ladder for shed inserts.  ``tl`` is an
    optional :class:`BatchTimeline` — when given, every measured batch is
    recorded with fenced phases, counter deltas and retry latency (the
    telemetry path); when None the run is bare (the overhead baseline)."""
    _pool, meta, mesh, cfg, bounds, state, sharding = _mesh_setup(dataset)
    host = HostBTree(dataset, dataset * 7, fill=0.7)
    eng_fn = engine_mod.make_dex_engine(meta, cfg, mesh, ops=ops_set,
                                        max_count=MC)
    eng = jax.jit(eng_fn)
    smo = jax.jit(smo_mod.make_dex_smo(meta, cfg, mesh))

    wl = ycsb.generate(name, dataset, (n_warm + n_batches) * batch,
                       theta=0.99, seed=11, scan_len=SCAN_LEN,
                       scan_len_dist="uniform")

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    # static communication plan + traced collective counts (first batch).
    # This traces eng_fn itself — the exact program the steady-state batch
    # dispatches whether or not telemetry wraps the call (the obs layer is
    # pure host code around the jitted callable), so these counts ARE the
    # telemetered batch's collective counts.
    opc0, kk0, vv0 = ycsb.engine_lanes(wl, 0, batch, update_xor=UPDATE_XOR)
    counts = routing.trace_collective_counts(
        eng_fn, state, jnp.asarray(opc0), jnp.asarray(kk0), jnp.asarray(vv0)
    )
    plan = eng_fn.plan
    if tl is not None:
        tl.meta["collectives_per_batch"] = dict(counts)
        tl.meta["plan"] = {k: v for k, v in plan.items() if k != "phases"}

    completed = 0
    batch_dts = []
    stats_warm = None
    for b in range(n_warm + n_batches):
        measured = b >= n_warm
        if b == n_warm:
            jax.block_until_ready(state.stats)
            stats_warm = np.asarray(state.stats).sum(axis=0)
            completed = 0
            batch_dts = []
            if tl is not None:
                tl.prime(state.stats)
        opc, kk, vv = ycsb.engine_lanes(
            wl, b * batch, (b + 1) * batch, update_xor=UPDATE_XOR
        )
        # the clock covers mesh execution only, fencing the FULL result
        # tree (state included) before reading it; host-replay validation
        # and the SMO settle ladder run off the clock on both paths, and
        # the throughput figure uses the median per-batch duration (robust
        # to GC / host-contention spikes on the emulated mesh)
        ob = None
        if tl is not None and measured:
            ob = tl.batch(name)
            with ob:
                state, found, vals, status, sk, sv, tk, done = (
                    engine_with_retries(eng, state, put, opc, kk, vv,
                                        max_retries=MAX_RETRIES, obs=ob)
                )
                ob.counters(state.stats)
            # dispatch phases only (engine + shed-lane replays), matching
            # the bare path's clock
            dt = sum(p.dur for p in ob.record.phases
                     if p.name == "engine" or p.name.startswith("retry/"))
        else:
            (state, found, vals, status, sk, sv, tk, done), dt = timed_batch(
                engine_with_retries, eng, state, put, opc, kk, vv,
                max_retries=MAX_RETRIES,
            )
        batch_dts.append(dt)
        completed += int((done & (kk != KEY_MAX)).sum())
        shed = _phased_host_replay(host, rng, opc, kk, vv, found, vals,
                                   status, sk, sv, tk, done)
        if shed.any():
            # SMO settlement runs off the clock but its rounds still show
            # up as smo/* phases in the trace (core/smo.py phase hooks)
            state, meta2, info = smo_mod.settle_splits(
                state, meta, cfg, smo, host,
                np.where(shed, kk, KEY_MAX), np.where(shed, vv, 0), bounds,
                obs=ob,
            )
            if info["drained"]:
                meta = meta2
                state = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), state,
                    dex_mod.state_shardings(mesh, cfg),
                )
                eng_fn = engine_mod.make_dex_engine(meta, cfg, mesh,
                                                    ops=ops_set, max_count=MC)
                eng = jax.jit(eng_fn)
                smo = jax.jit(smo_mod.make_dex_smo(meta, cfg, mesh))
    jax.block_until_ready(state.stats)
    stats = np.asarray(state.stats).sum(axis=0) - stats_warm
    tput = (completed / len(batch_dts)) / float(np.median(batch_dts))
    return dict(tput=tput, completed=completed, counts=counts,
                plan=plan, stats=stats)


def _run_split_path(name, ops_set, dataset, n_batches, n_warm, rng,
                    batch):
    """The pre-engine baseline: one masked single-opcode program per op
    type, each with its own route round / descent / write round."""
    _pool, meta, mesh, cfg, bounds, state, sharding = _mesh_setup(dataset)
    host = HostBTree(dataset, dataset * 7, fill=0.7)

    def build():
        progs = {}
        if "lookup" in ops_set:
            progs["lookup"] = (
                dex_mod.make_dex_lookup(meta, cfg, mesh))
        if "update" in ops_set:
            progs["update"] = (
                write_mod.make_dex_update(meta, cfg, mesh))
        if "insert" in ops_set:
            progs["insert"] = (
                write_mod.make_dex_insert(meta, cfg, mesh))
        if "scan" in ops_set:
            progs["scan"] = (
                scan_mod.make_dex_scan(meta, cfg, mesh, max_count=MC))
        return progs

    progs = build()
    # traced collective counts: the sum over the split programs
    b0 = np.zeros(batch, np.int64)
    counts = {"all_to_all": 0, "route_exchange": 0}
    for kind, fn in progs.items():
        if kind == "lookup":
            c = routing.trace_collective_counts(fn, state, jnp.asarray(b0))
        elif kind == "scan":
            c = routing.trace_collective_counts(
                fn, state, jnp.asarray(b0), jnp.asarray(b0))
        else:
            c = routing.trace_collective_counts(
                fn, state, jnp.asarray(b0), jnp.asarray(b0))
        for k in counts:
            counts[k] += c[k]
    progs = {k: jax.jit(v) for k, v in build().items()}
    smo = jax.jit(smo_mod.make_dex_smo(meta, cfg, mesh))

    wl = ycsb.generate(name, dataset, (n_warm + n_batches) * batch,
                       theta=0.99, seed=11, scan_len=SCAN_LEN,
                       scan_len_dist="uniform")

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    completed = 0
    batch_dts = []
    for b in range(n_warm + n_batches):
        if b == n_warm:
            jax.block_until_ready(state.stats)
            completed = 0
            batch_dts = []
        dt = 0.0
        opc, kk, vv = ycsb.engine_lanes(
            wl, b * batch, (b + 1) * batch, update_xor=UPDATE_XOR
        )
        # the split path masks the mixed stream into per-op-type batches;
        # the clock covers the three programs' mesh execution only, like
        # the engine path's
        if "lookup" in progs:
            lk = np.where(opc == ycsb.OP_LOOKUP, kk, KEY_MAX)
            (state, _f, _v, done_l), d = timed_batch(
                lookup_with_retries, progs["lookup"], state, put, lk,
                max_retries=MAX_RETRIES)
            dt += d
            completed += int((done_l & (lk != KEY_MAX)).sum())
        if "update" in progs:
            uk = np.where(opc == ycsb.OP_UPDATE, kk, KEY_MAX)
            (state, ru), d = timed_batch(
                write_with_retries, progs["update"], state, put, uk,
                np.where(opc == ycsb.OP_UPDATE, vv, 0),
                max_retries=MAX_RETRIES)
            dt += d
            completed += int(
                ((uk != KEY_MAX) & (ru != write_mod.STATUS_SHED)).sum())
            # mirror applied updates: a drain_splits rebuild reconstructs
            # the pool from the host, so unmirrored updates would revert
            ok_u = (uk != KEY_MAX) & (ru == write_mod.STATUS_OK)
            for k, v in zip(uk[ok_u], vv[ok_u]):
                host.update(int(k), int(v))
        if "insert" in progs:
            ik = np.where(opc == ycsb.OP_INSERT, kk, KEY_MAX)
            (state, ri), d = timed_batch(
                write_with_retries, progs["insert"], state, put, ik,
                np.where(opc == ycsb.OP_INSERT, vv, 0),
                max_retries=MAX_RETRIES)
            dt += d
            completed += int(
                ((ik != KEY_MAX) & (ri != write_mod.STATUS_SHED)).sum())
            for k in ik[(ik != KEY_MAX) & (ri == write_mod.STATUS_OK)]:
                host.insert(int(k), int(k))
            shed = (ik != KEY_MAX) & (ri == write_mod.STATUS_SPLIT)
            if shed.any():
                state, meta2, info = smo_mod.settle_splits(
                    state, meta, cfg, smo, host,
                    np.where(shed, ik, KEY_MAX),
                    np.where(shed, np.where(opc == ycsb.OP_INSERT, vv, 0), 0),
                    bounds,
                )
                if info["drained"]:
                    meta = meta2
                    state = jax.tree.map(
                        lambda x, s: jax.device_put(x, s), state,
                        dex_mod.state_shardings(mesh, cfg),
                    )
                    progs = {k: jax.jit(v) for k, v in build().items()}
                    smo = jax.jit(smo_mod.make_dex_smo(meta, cfg, mesh))
        if "scan" in progs:
            sk_in = np.where(opc == ycsb.OP_SCAN, kk, KEY_MAX)
            cnts = np.where(opc == ycsb.OP_SCAN, vv, 0)
            (state, _k, _v, _t, done_s), d = timed_batch(
                scan_with_retries, progs["scan"], state, put, sk_in, cnts,
                max_count=MC, max_retries=MAX_RETRIES)
            dt += d
            completed += int((done_s & (sk_in != KEY_MAX)).sum())
        batch_dts.append(dt)
    jax.block_until_ready(state.stats)
    tput = (completed / len(batch_dts)) / float(np.median(batch_dts))
    return dict(tput=tput, completed=completed, counts=counts)


def _run_group_offload(dataset, n_warm, n_batches, rng, batch):
    """Part 2: the per-group cost model serves a warm column one-sided and
    cold columns two-sided in the same batch; group counts cross-validate
    against the simulator on the identical trace."""
    # faster EMA decay + eager leaf admission so the warm/cold contrast
    # forms inside a short benchmark run; both planes use the same knobs
    _pool, meta, mesh, cfg_auto, bounds, state, sharding = _mesh_setup(
        dataset, policy="auto", cache_sets=2048, ema_decay=0.5,
        p_admit_leaf_pct=100,
    )
    cfg_fetch = dex_mod.DexMeshConfig(
        **{**cfg_auto.__dict__, "policy": "fetch"}
    )
    host = HostBTree(dataset, dataset * 7, fill=0.7)
    eng_fetch = jax.jit(engine_mod.make_dex_engine(
        meta, cfg_fetch, mesh, ops=("lookup", "update"), max_count=1))
    eng_auto = jax.jit(engine_mod.make_dex_engine(
        meta, cfg_auto, mesh, ops=("lookup", "update"), max_count=1))

    n_total = n_warm + n_batches
    wl = ycsb.generate("ycsb-a", dataset, n_batches * batch, theta=0.99,
                       seed=11, hotspot=0.1)
    # warm phase: a dense forced-fetch lookup sweep of the hot column's key
    # range (the hotspot center 0.1 lies inside memory column 0, whose
    # whole leaf population fits the per-chip cache) — its per-(column,
    # level) miss EMA falls below the cost crossover while the untouched
    # columns stay cold at EMA 1.  The measured auto phase then exploits
    # exactly that contrast.  Both planes consume the identical stream.
    s_per = meta.n_subtrees_padded // cfg_auto.n_memory
    hot_n = min(dataset.size,
                -(-dataset.size * s_per // max(meta.n_subtrees, 1)))
    # lane order is what routes a key to a serving chip (source-dispersed
    # within the route row), so each warm batch re-permutes the sweep:
    # every chip ends up caching every hot-column leaf, and the measured
    # phase's differently-ordered lanes keep hitting
    rng_w = np.random.default_rng(23)
    warm_keys = np.concatenate([
        rng_w.permutation(
            dataset[(np.arange(batch) * hot_n // batch + 17 * b) % hot_n]
        )
        for b in range(n_warm)
    ]).astype(np.int64)
    warm_ops = np.zeros(warm_keys.shape, np.int32)       # all lookups
    wl_all = ycsb.Workload(
        ops=np.concatenate([warm_ops, wl.ops]),
        keys=np.concatenate([warm_keys, wl.keys]),
        scan_len=wl.scan_len,
    )

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    def grp(stats):
        return (int(stats[dex_mod.STAT_OFFLOAD_GROUPS]),
                int(stats[dex_mod.STAT_FETCH_GROUPS]))

    both_in_one_batch = False
    stats_warm = None
    for b in range(n_total):
        eng = eng_fetch if b < n_warm else eng_auto
        if b == n_warm:
            jax.block_until_ready(state.stats)
            stats_warm = np.asarray(state.stats).sum(axis=0)
        before = np.asarray(state.stats).sum(axis=0)
        opc, kk, vv = ycsb.engine_lanes(
            wl_all, b * batch, (b + 1) * batch, update_xor=UPDATE_XOR
        )
        state, found, vals, status, _sk, _sv, _tk, done = engine_with_retries(
            eng, state, put, opc, kk, vv, max_retries=MAX_RETRIES
        )
        after = np.asarray(state.stats).sum(axis=0)
        if b >= n_warm:
            d_off = after[dex_mod.STAT_OFFLOAD_GROUPS] - before[
                dex_mod.STAT_OFFLOAD_GROUPS]
            d_f = after[dex_mod.STAT_FETCH_GROUPS] - before[
                dex_mod.STAT_FETCH_GROUPS]
            if d_off > 0 and d_f > 0:
                both_in_one_batch = True
        # host mirror: lookups see the pre-batch index, then updates apply
        lk_ok = np.where((opc == ycsb.OP_LOOKUP) & done)[0]
        for i in rng.choice(lk_ok, size=min(16, lk_ok.size), replace=False):
            hv = host.get(int(kk[i]))
            assert bool(found[i]) == (hv is not None), int(kk[i])
            if hv is not None:
                assert int(vals[i]) == hv, int(kk[i])
        for i in np.where((opc == ycsb.OP_UPDATE) & done)[0]:
            applied = host.update(int(kk[i]), int(vv[i]))
            assert (status[i] == write_mod.STATUS_OK) == applied, int(kk[i])
    stats = np.asarray(state.stats).sum(axis=0) - stats_warm
    mesh_off, mesh_fetch = grp(stats)

    # Plane A on the identical trace: same byte-cost rule, same windowing,
    # blocked subtree placement so both planes agree on column ownership
    sim_tree = HostBTree(
        dataset, dataset * 7, fill=0.7, level_m=1,
        n_mem_servers=cfg_auto.n_memory, placement="blocked",
        subtrees_per_server=meta.n_subtrees_padded // cfg_auto.n_memory,
    )
    sim_cfg = SimConfig(
        name="dex-engine", n_compute=cfg_auto.n_devices,
        n_mem_servers=cfg_auto.n_memory, level_m=1,
        write_through=True, offloading=True,
        group_offload=True, group_ema_decay=cfg_auto.ema_decay,
        coherence_batch=batch, route_dispersion=cfg_auto.n_memory,
        p_admit_leaf=cfg_auto.p_admit_leaf_pct / 100.0,
        cache_bytes=cfg_auto.cache_sets * cfg_auto.cache_ways * 1024,
        offload_c=cfg_auto.offload_c,
    )
    sim = Simulator(sim_tree, sim_cfg, seed=3)
    warm = slice(0, n_warm * batch)
    meas = slice(n_warm * batch, n_total * batch)
    sim.run(wl_all.ops[warm], wl_all.keys[warm], group_policy="fetch")
    sim.reset_counters()
    sim.run(wl_all.ops[meas], wl_all.keys[meas])
    t = sim.totals()
    return dict(
        mesh_offload_groups=mesh_off, mesh_fetch_groups=mesh_fetch,
        sim_offload_groups=t.offload_groups, sim_fetch_groups=t.fetch_groups,
        both_in_one_batch=both_in_one_batch,
        mesh_offload_msgs=int(stats[dex_mod.STAT_OFFLOADS]),
        _stats=stats, _sim=t,
    )


#: part-3 opcode set — YCSB-A has no scans, and inserts keep the write
#: plane (and the pipelined version story) fully exercised
SUS_OPS = ("lookup", "update", "insert")


def _sustained_replay(host, opc, kk, vv, found, vals, status, shed):
    """Validate EVERY lane of one sustained-service batch against the
    phased host replay (reads see the pre-batch index, then updates, then
    inserts).  Sustained mode runs shed-free by construction (route
    capacity covers the whole local batch), so any shed lane is a loud
    failure, not a retry."""
    assert not shed.any(), f"{int(shed.sum())} shed lanes in sustained mode"
    live = kk != KEY_MAX
    for i in np.where(live & (opc == ycsb.OP_LOOKUP))[0]:
        hv = host.get(int(kk[i]))
        assert bool(found[i]) == (hv is not None), int(kk[i])
        if hv is not None:
            assert int(vals[i]) == hv, int(kk[i])
    for i in np.where(live & (opc == ycsb.OP_UPDATE))[0]:
        applied = host.update(int(kk[i]), int(vv[i]))
        assert (status[i] == write_mod.STATUS_OK) == applied, int(kk[i])
    ins = live & (opc == ycsb.OP_INSERT)
    assert not (ins & (status == write_mod.STATUS_SPLIT)).any()
    for i in np.where(ins & (status == write_mod.STATUS_OK))[0]:
        host.insert(int(kk[i]), int(vv[i]))


def _sustained_sync(dataset, wl, n_warm, n_sus, batch):
    """Batch-synchronous service arm: each batch's results are
    materialised on the host before the next batch is admitted."""
    _pool, meta, mesh, cfg, bounds, state, sharding = _mesh_setup(dataset)
    host = HostBTree(dataset, dataset * 7, fill=0.7)
    eng_fn = engine_mod.make_dex_engine(meta, cfg, mesh, ops=SUS_OPS,
                                        max_count=1)
    eng = jax.jit(eng_fn)

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    def lanes(b):
        return ycsb.engine_lanes(wl, b * batch, (b + 1) * batch,
                                 update_xor=UPDATE_XOR)

    opc0, kk0, vv0 = lanes(0)
    counts = routing.trace_collective_counts(
        eng_fn, state, jnp.asarray(opc0), jnp.asarray(kk0), jnp.asarray(vv0)
    )

    outs = []
    stats0 = None
    t0 = 0.0
    for b in range(n_warm + n_sus):
        if b == n_warm:
            jax.block_until_ready(state.stats)
            stats0 = np.asarray(state.stats).sum(axis=0)
            t0 = time.perf_counter()
        opc, kk, vv = lanes(b)
        state, r = eng(state, put(opc.astype(np.int32)), put(kk), put(vv))
        outs.append((np.asarray(r.found), np.asarray(r.values),
                     np.asarray(r.status), np.asarray(r.shed)))
    wall = time.perf_counter() - t0
    stats = np.asarray(state.stats).sum(axis=0) - stats0
    # the mirror replays every batch in stream order (warm included — its
    # writes are part of the index the measured window reads)
    n_ops = 0
    for b in range(n_warm + n_sus):
        opc, kk, vv = lanes(b)
        _sustained_replay(host, opc, kk, vv, *outs[b])
        if b >= n_warm:
            n_ops += int((kk != KEY_MAX).sum())
    return dict(wall=wall, tput=n_ops / wall, counts=counts, stats=stats,
                outs=outs[n_warm:], cfg=cfg, meta=meta)


def _sustained_pipe(dataset, wl, n_warm, n_sus, batch, tl=None):
    """Pipelined service arm: prologue / steady state / drain over the same
    trace, results delivered one batch behind the pushes.  ``tl`` records
    each batch's cross-step lifetime: its ``pipe/front`` span is step ``s``
    and its ``pipe/back`` span is step ``s+1`` — the overlap windows
    legitimately interleave adjacent batch records in the trace export."""
    _pool, meta, mesh, cfg, bounds, state, sharding = _mesh_setup(dataset)
    host = HostBTree(dataset, dataset * 7, fill=0.7)
    pipe = engine_mod.make_dex_engine(meta, cfg, mesh, ops=SUS_OPS,
                                      max_count=1, pipeline=True)

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    def lanes(b):
        return ycsb.engine_lanes(wl, b * batch, (b + 1) * batch,
                                 update_xor=UPDATE_XOR)

    def fetch(r):
        return (np.asarray(r.found), np.asarray(r.values),
                np.asarray(r.status), np.asarray(r.shed))

    opc0, kk0, vv0 = lanes(0)
    counts = routing.trace_collective_counts(
        pipe.step_fn, state, pipe.init_carry(batch),
        jnp.asarray(opc0), jnp.asarray(kk0), jnp.asarray(vv0),
        by_phase=True,
    )
    if tl is not None:
        tl.meta["collectives_per_batch"] = {
            k: v for k, v in counts.items() if k != "phases"
        }
        tl.meta["collectives_by_phase"] = counts["phases"]
        tl.meta["plan"] = {k: v for k, v in pipe.plan.items()
                           if k != "phases"}

    results = {}
    # warm cycle (compile + cache fill), flushed so the measured window
    # starts from a fully-applied index
    pipe.start(state)
    for b in range(n_warm):
        opc, kk, vv = lanes(b)
        r = pipe.push(put(opc.astype(np.int32)), put(kk), put(vv))
        if r is not None:
            results[b - 1] = fetch(r)
    r = pipe.drain()
    if r is not None:
        results[n_warm - 1] = fetch(r)
    jax.block_until_ready(pipe.state.stats)
    stats0 = np.asarray(pipe.state.stats).sum(axis=0)
    if tl is not None:
        tl.prime(pipe.state.stats)

    # measured cycle: the wall pays the prologue and drain boundary like
    # any real service interval
    prev_ob = None
    prev_idx = None
    t0 = time.perf_counter()
    for b in range(n_warm, n_warm + n_sus):
        opc, kk, vv = lanes(b)
        ob = tl.open_batch("ycsb-a") if tl is not None else None
        ts0 = time.perf_counter()
        r = pipe.push(put(opc.astype(np.int32)), put(kk), put(vv))
        if r is not None:
            results[prev_idx] = fetch(r)
        ts1 = time.perf_counter()
        if tl is not None:
            ob.add_span("pipe/front", ts0, ts1 - ts0)
            if prev_ob is not None:
                prev_ob.add_span("pipe/back", ts0, ts1 - ts0)
                prev_ob.counters(pipe.state.stats)
                prev_ob.close()
        prev_ob, prev_idx = ob, b
    ts0 = time.perf_counter()
    r = pipe.drain()
    results[prev_idx] = fetch(r)
    ts1 = time.perf_counter()
    if tl is not None and prev_ob is not None:
        prev_ob.add_span("pipe/back", ts0, ts1 - ts0)
        prev_ob.counters(pipe.state.stats)
        prev_ob.close()
    jax.block_until_ready(pipe.state.stats)
    wall = time.perf_counter() - t0
    stats = np.asarray(pipe.state.stats).sum(axis=0) - stats0

    n_ops = 0
    for b in range(n_warm + n_sus):
        opc, kk, vv = lanes(b)
        _sustained_replay(host, opc, kk, vv, *results[b])
        if b >= n_warm:
            n_ops += int((kk != KEY_MAX).sum())
    return dict(wall=wall, tput=n_ops / wall, counts=counts, stats=stats,
                outs=[results[b] for b in range(n_warm, n_warm + n_sus)])


def _sustained_model(dataset, wl, n_warm, n_sus, batch, cfg, meta):
    """Plane A: the simulator prices the identical trace with and without
    ``pipeline_overlap`` (write-back round hidden, conflict stalls charged)
    and the cost model converts both into sustained throughput."""
    reports = {}
    totals = {}
    for overlap in (False, True):
        sim_tree = HostBTree(
            dataset, dataset * 7, fill=0.7, level_m=1,
            n_mem_servers=cfg.n_memory, placement="blocked",
            subtrees_per_server=meta.n_subtrees_padded // cfg.n_memory,
        )
        sim_cfg = SimConfig(
            name="dex-engine", n_compute=cfg.n_devices,
            n_mem_servers=cfg.n_memory, level_m=1,
            write_through=True, offloading=False,
            coherence_batch=batch, route_dispersion=cfg.n_memory,
            p_admit_leaf=cfg.p_admit_leaf_pct / 100.0,
            cache_bytes=cfg.cache_sets * cfg.cache_ways * 1024,
            pipeline_overlap=overlap,
        )
        sim = Simulator(sim_tree, sim_cfg, seed=3)
        warm = slice(0, n_warm * batch)
        meas = slice(n_warm * batch, (n_warm + n_sus) * batch)
        sim.run(wl.ops[warm], wl.keys[warm])
        sim.reset_counters()
        sim.run(wl.ops[meas], wl.keys[meas])
        key = "pipe" if overlap else "sync"
        reports[key] = cost_model.analyze(sim, threads_total=144)
        totals[key] = sim.totals()
    return reports, totals


def run(quick: bool = False, seed: "int | None" = None):
    base_seed = 0 if seed is None else int(seed)
    n_keys = 30_000 if quick else 100_000
    n_batches = 3 if quick else 6
    n_warm = 2 if quick else 4
    batch = 512 if quick else BATCH
    rng = np.random.default_rng(base_seed + 5)
    dataset = ycsb.make_dataset(n_keys, seed=base_seed)
    rows = ["plane,workload,metric,value"]
    summary = {}

    tel_tputs = {}
    for name, ops_set in MIXES:
        tl = common.new_timeline(f"fig13engine_{name}",
                                 devices=len(jax.devices()), batch=batch)
        eng = _run_engine_path(name, ops_set, dataset, n_batches, n_warm,
                               rng, batch, tl=tl)
        tel_tputs[name] = eng["tput"]
        common.finish_timeline(tl)
        split = _run_split_path(name, ops_set, dataset, n_batches, n_warm,
                                rng, batch)
        # ONE route round + ONE fused pair per mixed batch, vs one route
        # round per op-type program on the split path
        assert eng["counts"]["route_exchange"] == 2, eng["counts"]
        assert eng["plan"]["fused_pairs"] == 1, eng["plan"]
        assert split["counts"]["route_exchange"] == 2 * len(ops_set)
        assert eng["counts"]["all_to_all"] < split["counts"]["all_to_all"], (
            name, eng["counts"], split["counts"]
        )
        # same completed work, fewer programs: the engine must not be slower
        assert eng["tput"] >= 0.9 * split["tput"], (
            f"{name}: engine {eng['tput']:.0f} ops/s vs split "
            f"{split['tput']:.0f} ops/s"
        )
        rows += [
            f"engine,{name},ops_per_s,{eng['tput']:.1f}",
            f"engine,{name},completed_ops,{eng['completed']}",
            f"engine,{name},a2a_per_batch,{eng['counts']['all_to_all']}",
            f"engine,{name},route_rounds,1",
            f"split,{name},ops_per_s,{split['tput']:.1f}",
            f"split,{name},completed_ops,{split['completed']}",
            f"split,{name},a2a_per_batch,{split['counts']['all_to_all']}",
            f"split,{name},route_rounds,{len(ops_set)}",
        ]
        summary[f"{name}_engine_ops_per_s"] = eng["tput"]
        summary[f"{name}_split_ops_per_s"] = split["tput"]
        summary[f"{name}_engine_a2a"] = eng["counts"]["all_to_all"]
        summary[f"{name}_split_a2a"] = split["counts"]["all_to_all"]
        summary[f"{name}_speedup"] = eng["tput"] / max(split["tput"], 1e-9)

    # telemetry overhead + zero-added-collectives proof: re-run the first
    # mix bare (no timeline).  The obs layer is host-side only, so the
    # traced collective counts of the steady-state batch must be identical
    # — and the telemetered throughput must stay within 5% of bare.
    ov_name, ov_ops = MIXES[0]
    bare = _run_engine_path(ov_name, ov_ops, dataset, n_batches, n_warm,
                            rng, batch, tl=None)
    tel_ratio = tel_tputs[ov_name] / max(bare["tput"], 1e-9)
    rows.append(f"engine,{ov_name},telemetry_tput_ratio,{tel_ratio:.3f}")
    summary["telemetry_tput_ratio"] = tel_ratio
    assert tel_ratio >= 0.95, (
        f"telemetry overhead too high: {tel_tputs[ov_name]:.0f} ops/s "
        f"telemetered vs {bare['tput']:.0f} ops/s bare"
    )
    # the telemetered run recorded its traced counts in the timeline meta
    tel_counts = (
        common.TELEMETRY[f"fig13engine_{ov_name}"]["meta"]
        ["collectives_per_batch"]
    )
    assert tel_counts == dict(bare["counts"]), (
        f"instrumentation changed the traced program: {tel_counts} vs "
        f"{bare['counts']}"
    )
    summary["telemetry_added_collectives"] = float(
        sum(tel_counts.values()) - sum(bare["counts"].values())
    )

    g = _run_group_offload(dataset, 10 if quick else 14,
                           4 if quick else 8, rng, batch)
    rows += [
        f"engine,group,mesh_offload_groups,{g['mesh_offload_groups']}",
        f"engine,group,mesh_fetch_groups,{g['mesh_fetch_groups']}",
        f"sim,group,offload_groups,{g['sim_offload_groups']}",
        f"sim,group,fetch_groups,{g['sim_fetch_groups']}",
        f"engine,group,both_groups_in_one_batch,{int(g['both_in_one_batch'])}",
    ]
    summary.update(
        {k: float(v) for k, v in g.items() if not k.startswith("_")}
    )
    if len(jax.devices()) >= 8:
        # a cold column offloads while the warm one fetches, in ONE batch
        assert g["both_in_one_batch"], g
        assert g["mesh_offload_groups"] > 0 and g["mesh_fetch_groups"] > 0, g
        assert g["sim_offload_groups"] > 0 and g["sim_fetch_groups"] > 0, g
        # both planes priced the identical trace with the identical rule:
        # the per-group offload counts must agree (registry-named snapshot
        # vs sim Counters through the shared drift helper)
        drift.assert_plane_agreement(
            registry.snapshot(g["_stats"][None, :]),
            g["_sim"],
            {"offload_groups": drift.ratio(0.66, 1.5)},
            label="fig13engine group offload",
        )

    # ------------------------------------------------------------------
    # Part 3: continuous-service pipelining on the YCSB-A trace
    # ------------------------------------------------------------------
    n_wp = 2 if quick else 3
    n_sus = 6 if quick else 10
    wl_sus = ycsb.generate("ycsb-a", dataset, (n_wp + n_sus) * batch,
                           theta=0.99, seed=11)
    sync = _sustained_sync(dataset, wl_sus, n_wp, n_sus, batch)
    tl_p = common.new_timeline("fig13engine_pipeline",
                               devices=len(jax.devices()), batch=batch,
                               mode="pipelined")
    pipe = _sustained_pipe(dataset, wl_sus, n_wp, n_sus, batch, tl=tl_p)
    common.finish_timeline(tl_p)

    # pipelined results are bit-identical to the synchronous service's,
    # lane for lane across every measured batch (version checks + the
    # conservative conflict stall close the overlap window)
    for b, (so, po) in enumerate(zip(sync["outs"], pipe["outs"])):
        for a_s, a_p in zip(so, po):
            np.testing.assert_array_equal(a_s, a_p,
                                          err_msg=f"sustained batch {b}")

    # one pipelined step == one synchronous program, collective for
    # collective; the fused write round sits in the back half
    pipe_tot = {k: v for k, v in pipe["counts"].items() if k != "phases"}
    assert pipe_tot == dict(sync["counts"]), (pipe_tot, sync["counts"])
    ph = pipe["counts"]["phases"]
    assert set(ph) == {"pipe/front", "pipe/back"}, ph
    assert ph["pipe/back"].get("all_to_all", 0) >= 2, ph

    stalls_pipe = int(pipe["stats"][dex_mod.STAT_PIPE_STALLS])
    stalls_sync = int(sync["stats"][dex_mod.STAT_PIPE_STALLS])
    assert stalls_sync == 0, stalls_sync

    # Plane A: sustained throughput with the write-back round hidden
    reports, totals3 = _sustained_model(dataset, wl_sus, n_wp, n_sus,
                                        batch, sync["cfg"], sync["meta"])
    modeled_speedup = (reports["pipe"].ops_per_sec
                       / max(reports["sync"].ops_per_sec, 1e-9))
    wall_ratio = sync["wall"] / max(pipe["wall"], 1e-9)

    rows += [
        f"engine,ycsb-a,sync_sustained_ops_per_s,{sync['tput']:.1f}",
        f"engine,ycsb-a,pipeline_sustained_ops_per_s,{pipe['tput']:.1f}",
        f"engine,ycsb-a,pipeline_wall_ratio,{wall_ratio:.3f}",
        f"engine,ycsb-a,pipeline_stall_lanes,{stalls_pipe}",
        f"sim,ycsb-a,pipeline_stalls,{totals3['pipe'].pipeline_stalls}",
        f"model,ycsb-a,sync_mops,{reports['sync'].mops():.3f}",
        f"model,ycsb-a,pipeline_mops,{reports['pipe'].mops():.3f}",
        f"model,ycsb-a,pipeline_speedup,{modeled_speedup:.3f}",
    ]
    summary["ycsb-a_sync_sustained_ops_per_s"] = sync["tput"]
    summary["ycsb-a_pipeline_sustained_ops_per_s"] = pipe["tput"]
    summary["pipeline_wall_ratio"] = wall_ratio
    summary["pipeline_stall_lanes"] = float(stalls_pipe)
    summary["pipeline_sim_stalls"] = float(totals3["pipe"].pipeline_stalls)
    summary["pipeline_modeled_speedup"] = modeled_speedup
    summary["pipeline_modeled_sync_mops"] = reports["sync"].mops()
    summary["pipeline_modeled_mops"] = reports["pipe"].mops()

    if len(jax.devices()) >= 8:
        # cross-batch same-leaf conflicts exist under zipfian skew, so the
        # overlap window must stall some lanes — and both planes price the
        # same conflict rule on the identical trace
        assert stalls_pipe > 0, "no overlap-window stalls on a zipfian trace"
        drift.assert_plane_agreement(
            registry.snapshot(pipe["stats"][None, :]),
            totals3["pipe"],
            {"pipeline_stalls": drift.ratio(0.25, 4.0)},
            label="fig13engine pipeline stalls",
        )
    # the paper's sustained-throughput claim, priced: hiding the write
    # round beats batch-synchronous by >= 1.15x net of stall costs.  The
    # emulated mesh time-shares host cores, so the wall-clock ratio is
    # recorded above but only sanity-bounded here (pipelining must not
    # cost more than a third of sync throughput in overheads).
    assert modeled_speedup >= 1.15, (
        f"modeled sustained speedup {modeled_speedup:.3f} < 1.15"
    )
    assert wall_ratio >= 0.67, (
        f"pipelined wall-clock overhead too high: ratio {wall_ratio:.3f}"
    )
    return rows, summary


def main():
    quick = "--quick" in sys.argv
    rows, summary = run(quick=quick)
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k} = {v}")


if __name__ == "__main__":
    main()
