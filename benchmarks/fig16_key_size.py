"""Fig. 16: key-size sensitivity (8B -> 64B keys in fixed 1KB nodes).

Larger keys shrink effective fanout, deepening the tree and stressing the
fixed-size cache; the paper shows both DEX and SMART degrade but DEX keeps
its advantage.  We model key size by reducing per-node fanout (64 keys at
8B -> 8 keys at 64B) through a smaller bulk-load fill.

Two planes per key size:

* **Plane A (cost model)** — the original DEX-vs-SMART Mops comparison.
* **Plane B (mesh)** — the same reduced-fill pool bulk-loaded onto the
  forced-8-device mesh; a Zipfian lookup stream reports the *measured*
  descent depth (pool levels) and remote reads per op (``fetches/ops``
  via the registry's ``remote_reads_per_op`` derived metric).  Depth grows
  as fill shrinks, and the remote reads per op grow with it — the
  mechanism behind the paper's degradation curve.
* **Compressed separators** (DESIGN.md §13) — ``pool.compress_separators``
  on the same pool reports how much of the depth penalty the
  shared-prefix + truncated-suffix layout wins back: per-row separator
  bytes drop from ``8*F`` to ``8 + 4 + 4*F``, and the byte-equivalent
  effective fanout feeds a modeled subtree depth at equal node budget.
"""

import os
import pathlib
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from benchmarks.common import (  # noqa: E402
    HEADER,
    N_KEYS,
    N_OPS,
    N_WARM,
    lookup_with_retries,
)
from repro.compat import make_mesh_compat  # noqa: E402
from repro.core import baselines  # noqa: E402
from repro.core import dex as dex_mod  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core.cost_model import analyze  # noqa: E402
from repro.core.nodes import KEY_MAX, KEY_MIN  # noqa: E402
from repro.core.sim import HostBTree, Simulator  # noqa: E402
from repro.data import ycsb  # noqa: E402
from repro.obs import registry  # noqa: E402


def _mesh_key_size(dataset, fill, *, batch, n_warm, n_meas, seed):
    """One mesh lookup run at the reduced fill modeling this key size.
    Returns measured descent depth, remote reads per op, and the
    compressed-separator layout stats of the same pool."""
    vals = dataset * 7
    pool, meta = pool_mod.build_pool(dataset, vals, level_m=1, fill=fill,
                                     n_shards=4)
    if len(jax.devices()) >= 8:
        shape, n_route, n_memory = (2, 4), 2, 4
        mid = int(dataset[dataset.size // 2])
        bounds = np.array([KEY_MIN, mid, KEY_MAX], dtype=np.int64)
    else:
        shape, n_route, n_memory = (1, 1), 1, 1
        bounds = np.array([KEY_MIN, KEY_MAX], dtype=np.int64)
    mesh = make_mesh_compat(shape, ("data", "model"))
    cfg = dex_mod.DexMeshConfig(
        route_axes=("data",), memory_axis="model",
        n_route=n_route, n_memory=n_memory,
        cache_sets=64, cache_ways=4, policy="fetch",
        route_capacity_factor=float(max(2, n_memory)),
    )
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state,
        dex_mod.state_shardings(mesh, cfg),
    )
    sharding = NamedSharding(mesh, P(("data", "model")))
    lookup = jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh))

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    zipf = ycsb.ZipfianGenerator(dataset.size, theta=0.99, seed=seed)
    keys = dataset[ycsb.scramble(
        zipf.draw_ranks((n_warm + n_meas) * batch), dataset.size)]
    stats_warm = None
    for b in range(n_warm + n_meas):
        if b == n_warm:
            jax.block_until_ready(state.stats)
            stats_warm = np.asarray(state.stats).sum(axis=0)
        kk = keys[b * batch: (b + 1) * batch]
        state, found, vals_out, done = lookup_with_retries(
            lookup, state, put, kk)
        ok = done & (kk != KEY_MAX)
        assert bool(np.asarray(found)[ok].all()), "bulk-loaded key not found"
        assert (np.asarray(vals_out)[ok] == kk[ok] * 7).all()
    jax.block_until_ready(state.stats)
    snap = registry.snapshot(
        (np.asarray(state.stats).sum(axis=0) - stats_warm)[None, :])

    sep = pool_mod.compress_separators(pool, meta)
    sep_stats = pool_mod.sep_compression_stats(sep, meta)
    return dict(
        # full descent depth: compute-local top-tree levels + the remote
        # subtree walk (level_m inner levels + the leaf)
        descent_depth=meta.top_height + meta.level_m + 1,
        subtree_depth=meta.level_m + 1,
        remote_reads_per_op=snap["remote_reads_per_op"],
        per_node=meta.per_node,
        n_leaves=meta.subtree_leaves * meta.n_subtrees,
        sep=sep_stats,
    )


def run(quick: bool = False, seed: "int | None" = None):
    s = 0 if seed is None else int(seed)
    rows = [HEADER]
    summary = {}
    key_sizes = [8, 16] if quick else [8, 16, 32, 64]
    mesh_keys = 8_000 if quick else 24_000
    batch = 256 if quick else 512
    for ks in key_sizes:
        fill = 0.7 * 8 / ks          # effective entries per 1KB node
        for system in ["dex", "smart"]:
            dataset = ycsb.make_dataset(N_KEYS, seed=s)
            tree = HostBTree(dataset, fill=max(fill, 0.06), level_m=3,
                             n_mem_servers=4)
            cfg = baselines.ALL[system](
                cache_bytes=max(64, int(0.08 * tree.num_nodes)) * 1024
            )
            sim = Simulator(tree, cfg, seed=s + 9)
            warm = ycsb.generate("read-intensive", dataset, N_WARM, seed=s + 10)
            sim.run(warm.ops, warm.keys)
            sim.reset_counters()
            wl = ycsb.generate("read-intensive", dataset, N_OPS, seed=s + 11)
            sim.run(wl.ops, wl.keys)
            rep = analyze(sim, threads_total=144)
            rows.append(
                f"{system}-{ks}B,read-intensive,144,{rep.mops():.3f},"
                f"{rep.bottleneck},,,,,"
            )
            summary[f"{system}@{ks}B"] = rep.mops()
        # Plane B: the same reduced-fill geometry, measured on the mesh
        mesh_ds = ycsb.make_dataset(mesh_keys, seed=s)
        m = _mesh_key_size(mesh_ds, max(fill, 0.06), batch=batch,
                           n_warm=1, n_meas=2, seed=s + 13)
        rows.append(
            f"mesh-{ks}B,lookup,{len(jax.devices())},,"
            f"depth={m['descent_depth']},"
            f"{m['remote_reads_per_op']:.3f},,,,"
        )
        summary[f"mesh@{ks}B_descent_depth"] = float(m["descent_depth"])
        summary[f"mesh@{ks}B_remote_reads_per_op"] = m["remote_reads_per_op"]
        summary[f"mesh@{ks}B_compressible_frac"] = (
            m["sep"]["compressible_frac"])
        summary[f"mesh@{ks}B_effective_fanout"] = m["sep"]["effective_fanout"]
        summary[f"mesh@{ks}B_modeled_subtree_depth"] = float(
            m["sep"]["modeled_subtree_depth"])
    # deeper trees must cost more remote reads per op, monotonically over
    # the swept key sizes (the paper's Fig. 16 mechanism, measured)
    rr = [summary[f"mesh@{ks}B_remote_reads_per_op"] for ks in key_sizes]
    dd = [summary[f"mesh@{ks}B_descent_depth"] for ks in key_sizes]
    assert all(b >= a for a, b in zip(dd, dd[1:])), dd
    if dd[-1] > dd[0]:
        assert rr[-1] > rr[0], (dd, rr)
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k}: {v:.2f}")


if __name__ == "__main__":
    main()
