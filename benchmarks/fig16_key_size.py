"""Fig. 16: key-size sensitivity (8B -> 64B keys in fixed 1KB nodes).

Larger keys shrink effective fanout, deepening the tree and stressing the
fixed-size cache; the paper shows both DEX and SMART degrade but DEX keeps
its advantage.  We model key size by reducing per-node fanout (64 keys at
8B -> 8 keys at 64B) through a smaller bulk-load fill."""

from benchmarks.common import HEADER, N_KEYS, N_OPS, N_WARM
from repro.core import baselines
from repro.core.cost_model import analyze
from repro.core.sim import HostBTree, Simulator
from repro.data import ycsb


def run(quick: bool = False, seed: "int | None" = None):
    s = 0 if seed is None else int(seed)
    rows = [HEADER]
    summary = {}
    key_sizes = [8, 16] if quick else [8, 16, 32, 64]
    for ks in key_sizes:
        fill = 0.7 * 8 / ks          # effective entries per 1KB node
        for system in ["dex", "smart"]:
            dataset = ycsb.make_dataset(N_KEYS, seed=s)
            tree = HostBTree(dataset, fill=max(fill, 0.06), level_m=3,
                             n_mem_servers=4)
            cfg = baselines.ALL[system](
                cache_bytes=max(64, int(0.08 * tree.num_nodes)) * 1024
            )
            sim = Simulator(tree, cfg, seed=s + 9)
            warm = ycsb.generate("read-intensive", dataset, N_WARM, seed=s + 10)
            sim.run(warm.ops, warm.keys)
            sim.reset_counters()
            wl = ycsb.generate("read-intensive", dataset, N_OPS, seed=s + 11)
            sim.run(wl.ops, wl.keys)
            rep = analyze(sim, threads_total=144)
            rows.append(
                f"{system}-{ks}B,read-intensive,144,{rep.mops():.3f},"
                f"{rep.bottleneck},,,,,"
            )
            summary[f"{system}@{ks}B"] = rep.mops()
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k}: {v:.2f} Mops")


if __name__ == "__main__":
    main()
