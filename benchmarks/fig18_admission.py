"""Fig. 18: leaf admission probability (P_A) sweep across cache sizes.

Paper claims: at 64-128MB caches, P_A=1% beats always-admit by up to +34%;
at 1GB lazy admission can cost ~7% — the optimum shifts with cache size."""

from benchmarks.common import HEADER, run_one, seed_kwargs

P_AS = [0.01, 0.05, 0.10, 0.20, 1.00]
RATIOS = [0.02, 0.08, 0.32]


def run(quick: bool = False, seed: "int | None" = None):
    skw = seed_kwargs(seed)
    rows = [HEADER]
    summary = {}
    ratios = RATIOS[:1] if quick else RATIOS
    pas = [0.01, 0.10, 1.00] if quick else P_AS
    for ratio in ratios:
        base = None
        for pa in pas:
            r = run_one(
                "dex", "read-intensive", cache_ratio=ratio,
                cfg_overrides=dict(p_admit_leaf=pa, offloading=False), **skw,
            )
            rows.append(f"dex-pa{pa:.2f}@{ratio:.0%}," + r.row().split(",", 1)[1])
            if pa == 1.00:
                base = r.report.mops()
            summary[f"pa={pa:.2f}@{ratio:.0%}"] = r.report.mops()
        if base:
            for pa in pas:
                summary[f"rel_pa={pa:.2f}@{ratio:.0%}"] = (
                    summary[f"pa={pa:.2f}@{ratio:.0%}"] / base
                )
    return rows, summary


def main():
    rows, summary = run()
    print("\n".join(rows))
    for k, v in summary.items():
        if k.startswith("rel_"):
            print(f"# {k}: {v:.2f}x vs always-admit")


if __name__ == "__main__":
    main()
