"""Table 2: RDMA statistics per index operation (144 threads, zipf 0.99).

Paper values for reference (reads/writes/atomics/two-sided/traffic-B):
    DEX (RO)       0.33 / 0    / 0    / 0.0002 / 333.9
    Sherman (RO)   3.02 / 0    / 0    / 0      / 1064.7
    SMART (RO)     1.44 / 0    / 0    / 0      / 997.0
    P-Sherman (RO) 1.00 / 0    / 0    / 0      / 1025.0
    P-SMART (RO)   1.15 / 0    / 0    / 0      / 397.4
    DEX (WI)       0.33 / 0.19 / 0    / 0.0001 / 524.1
    Sherman (WI)   2.71 / 0.99 / 0.59 / 0      / 1079.0
    SMART (WI)     1.45 / 0.11 / 0.11 / 0      / 1002.9
    P-Sherman (WI) 1.02 / 0.50 / 0    / 0      / 1054.4
    P-SMART (WI)   1.16 / 0.13 / 0    / 0      / 404.2
"""

from benchmarks.common import HEADER, run_one, seed_kwargs

SYSTEMS = ["dex", "sherman", "smart", "p-sherman", "p-smart"]


def run(quick: bool = False, seed: "int | None" = None):
    skw = seed_kwargs(seed)
    rows = [HEADER]
    stats = {}
    for wl, tag in [("read-only", "RO"), ("write-intensive", "WI")]:
        for system in SYSTEMS:
            r = run_one(system, wl, n_warm=120_000, **skw)
            rows.append(r.row())
            stats[f"{system}({tag})"] = r.per_op
    return rows, stats


def main():
    rows, stats = run()
    print("\n".join(rows))
    d, s = stats["dex(RO)"], stats["sherman(RO)"]
    print(f"# DEX(RO) reads/op = {d['reads']:.2f} (paper: 0.33)")
    print(f"# rdma-op reduction vs Sherman: "
          f"{1 - d['reads'] / max(s['reads'], 1e-9):.0%} (paper: 89%)")


if __name__ == "__main__":
    main()
