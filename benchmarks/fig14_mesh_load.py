"""Fig. 14 companion: the insert-heavy load plane on the mesh — on-mesh SMO
(core/smo.py) vs the rebuild-drain fallback, head to head.

Bulk-load a dataset, then drive a 100%-insert trace (``ycsb-load``) through
``make_dex_insert`` on the forced-8-device mesh twice:

  * **smo**: leaf overflows resolve through the on-mesh SMO engine —
    device-side leaf splits allocated from the pool's free-list headroom,
    host ``drain_splits`` only for the residue (exhausted subtrees);
  * **drain**: every overflow replays through the host rebuild path — the
    pre-SMO behavior, restarting all caches and versions cold each time.

The trace targets the lower 80% of the key space so a probe set in the
untouched top decile can demonstrate warm-cache survival: in smo mode those
rows keep serving hits across splits (version bumps are surgical), in drain
mode one rebuild colds them all.  Results are cross-validated against a
``HostBTree`` mirror (bit-identical lookups and scans after all splits) and
against the event simulator pricing the same protocol (``dex-wt`` preset
with ``onmesh_smo=True``) on the identical trace: both planes' structural
split counts must agree.

Reported per mode: throughput, remote fetches per op (the protocol-level
cost where the drain path's global cold restart shows up — on the
CPU-emulated mesh wall-clock undercharges a rebuild, which is a local numpy
operation here but an O(dataset) network move on real disaggregated
memory), STAT_SPLITS (lanes shed by overflowing leaves), STAT_SMO_SPLITS
(splits executed device-side), drains (STAT_DRAINS), and the fraction of
shed lanes resolved without a rebuild — the headline claim is >= 90%
on-mesh.

Run with ``PYTHONPATH=src python benchmarks/fig14_mesh_load.py [--quick]``
or via the suite: ``PYTHONPATH=src python -m benchmarks.run --only
fig14meshload``.
"""

from __future__ import annotations

import os
import pathlib
import sys
import time

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import baselines  # noqa: E402
from repro.core import dex as dex_mod  # noqa: E402
from repro.core import pool as pool_mod  # noqa: E402
from repro.core import scan as scan_mod  # noqa: E402
from repro.core import smo as smo_mod  # noqa: E402
from repro.core import write as write_mod  # noqa: E402
from repro.core.nodes import KEY_MAX, KEY_MIN  # noqa: E402
from repro.compat import make_mesh_compat  # noqa: E402
from repro.core.sim import HostBTree, Simulator  # noqa: E402
from repro.data import ycsb  # noqa: E402
from repro.obs import drift  # noqa: E402

from benchmarks import common  # noqa: E402

BATCH = 1024
FILL = 0.85        # tighter leaf slack than the default 0.7 so a short
#                    insert trace reaches the structural-split regime
SUBTREE_LEAVES = 24  # small blocks: the block root starts with 24 children
#                    (40 separator slots of on-mesh split room vs the dense
#                    default's 10) and the dataset spreads over ~4x more
#                    subtrees / memory columns
HEADROOM = 2.0     # free-list sized past the root's separator room so the
#                    watermark never binds before the root does
TRACE_FRAC = 0.8   # inserts target the lower 80% of the key space; the
#                    top decile stays untouched for the cache-survival probe


def _build_ops(meta, cfg, mesh):
    return (
        jax.jit(dex_mod.make_dex_lookup(meta, cfg, mesh)),
        jax.jit(write_mod.make_dex_insert(meta, cfg, mesh)),
        jax.jit(smo_mod.make_dex_smo(meta, cfg, mesh)),
    )


def _run_mode(mode, dataset, ops_arr, keys_arr, n_warm_batches, rng):
    vals = dataset * 7
    pool, meta = pool_mod.build_pool(
        dataset, vals, level_m=1, fill=FILL, n_shards=4,
        subtree_leaves=SUBTREE_LEAVES, headroom=HEADROOM,
    )
    host = HostBTree(dataset, vals, fill=FILL)
    if len(jax.devices()) >= 8:
        shape, n_route, n_memory = (2, 4), 2, 4
        mid = int(dataset[dataset.size // 2])
        bounds = np.array([KEY_MIN, mid, KEY_MAX], dtype=np.int64)
    else:
        shape, n_route, n_memory = (1, 1), 1, 1
        bounds = np.array([KEY_MIN, KEY_MAX], dtype=np.int64)
    mesh = make_mesh_compat(shape, ("data", "model"))
    cfg = dex_mod.DexMeshConfig(
        route_axes=("data",), memory_axis="model",
        n_route=n_route, n_memory=n_memory,
        cache_sets=512, cache_ways=4,
        policy="fetch",
        p_admit_leaf_pct=100,   # deterministic leaf caching for the
        #                         warm-row survival probe
        route_capacity_factor=float(max(2, n_memory)),
    )
    state = dex_mod.init_state(pool, meta, cfg, bounds)
    shardings = dex_mod.state_shardings(mesh, cfg)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
    sharding = NamedSharding(mesh, P(("data", "model")))
    lookup, insert, smo = _build_ops(meta, cfg, mesh)

    def put(x):
        return jax.device_put(jnp.asarray(x), sharding)

    def reshard(state):
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s),
            state, dex_mod.state_shardings(mesh, cfg),
        )

    # survival probe: keys in the untouched top decile of the key space
    probe = dataset[-512:].astype(np.int64)
    state, pf, pv, _ = lookup(state, put(probe))
    assert bool(np.asarray(pf).all())

    n_total = ops_arr.size // BATCH
    shed_total = 0        # lanes shed by overflowing leaves (STAT_SPLITS)
    onmesh_total = 0      # shed lanes resolved device-side
    drains = 0
    stats_warm = None
    completed = 0
    surgical_checked = False
    survivor_frac = 1.0
    tl = common.new_timeline(
        f"fig14meshload_{mode}",
        devices=len(jax.devices()), batch=BATCH, mode=mode,
    )
    tl.prime(state.stats)
    t_start = time.perf_counter()
    for b in range(n_total):
        if b == n_warm_batches:
            jax.block_until_ready(state.stats)
            stats_warm = np.asarray(state.stats).sum(axis=0)
            tl.prime(state.stats)
            completed = 0
            t_start = time.perf_counter()
        bk = keys_arr[b * BATCH : (b + 1) * BATCH]
        bo = ops_arr[b * BATCH : (b + 1) * BATCH]
        ik = np.where(bo == ycsb.OP_INSERT, bk, KEY_MAX)
        ob = tl.batch(f"b{b}")
        ob.__enter__()
        with ob.phase("insert") as ph:
            state, ri = insert(state, put(ik), put(ik * 7))
            ph.fence((state, ri))
        ri = np.asarray(ri)
        live = ik != KEY_MAX
        completed += int((live & (ri != write_mod.STATUS_SHED)).sum())
        okm = live & (ri == write_mod.STATUS_OK)
        for kk in ik[okm]:
            host.insert(int(kk), int(kk) * 7)
        shed = live & (ri == write_mod.STATUS_SPLIT)
        if not shed.any():
            ob.counters(state.stats)
            ob.__exit__(None, None, None)
            continue
        shed_total += int(shed.sum())
        if mode == "smo":
            v_before = (
                None if surgical_checked
                else np.asarray(state.versions)[0].copy()
            )
            state, meta2, info = smo_mod.settle_splits(
                state, meta, cfg, smo, host,
                np.where(shed, ik, KEY_MAX), np.where(shed, ik * 7, 0),
                bounds, obs=ob,
            )
            onmesh_total += info["onmesh"]
            if not surgical_checked and info["onmesh"] and not info["drained"]:
                # surgical invalidation: the settle bumped only the split
                # leaves + siblings + ancestors, not the whole table
                v_after = np.asarray(state.versions)[0]
                changed = int((v_after != v_before).sum())
                n_real = int((np.asarray(state.occupancy) > 0).sum())
                survivor_frac = 1.0 - changed / max(n_real, 1)
                surgical_checked = True
            if info["drained"]:
                drains += 1
                meta = meta2
                state = reshard(state)
                lookup, insert, smo = _build_ops(meta, cfg, mesh)
        else:
            # pre-SMO behavior: every overflow rebuilds the pool from the
            # host replay, restarting caches and versions cold
            with ob.phase("smo/drain") as ph:
                state, meta = write_mod.drain_splits(
                    state, meta, cfg, host, ik[shed], ik[shed] * 7, bounds
                )
                ph.fence(state)
            drains += 1
            state = reshard(state)
            lookup, insert, smo = _build_ops(meta, cfg, mesh)
        ob.counters(state.stats)
        ob.__exit__(None, None, None)
    jax.block_until_ready(state)
    dt = time.perf_counter() - t_start
    common.finish_timeline(tl)
    stats = np.asarray(state.stats).sum(axis=0) - stats_warm

    # warm-row survival: the probe's leaves saw no writes (top decile is
    # outside the trace); smo mode must keep serving them from cache, a
    # drain-mode rebuild colds them
    before = np.asarray(state.stats).sum(axis=0)
    state, pf2, pv2, _ = lookup(state, put(probe))
    after = np.asarray(state.stats).sum(axis=0)
    probe_hits = int(after[dex_mod.STAT_HITS] - before[dex_mod.STAT_HITS])
    np.testing.assert_array_equal(np.asarray(pv2), probe * 7)

    # bit-identical to the host replay after all splits: lookups + scans
    hk, hv = write_mod.host_items(host)
    idx = rng.choice(hk.size, size=1024, replace=False)
    state, fa, va, _ = lookup(state, put(hk[idx]))
    fa, va = np.asarray(fa), np.asarray(va)
    assert fa.all(), f"{mode}: host keys missing on the mesh"
    np.testing.assert_array_equal(va, hv[idx])
    scan = jax.jit(scan_mod.make_dex_scan(meta, cfg, mesh, max_count=64))
    starts = np.sort(rng.choice(hk, size=512)).astype(np.int64)
    cnts = np.full(512, 48, np.int64)
    state, sk, sv, tk = scan(state, put(starts), put(cnts))
    sk, tk = np.asarray(sk), np.asarray(tk)
    for i in rng.choice(512, size=24, replace=False):
        if tk[i] < 0:
            continue
        expect = [
            kk for _, ks in host.scan(int(starts[i]), 48) for kk in ks
        ][:48]
        got = sk[i][sk[i] != KEY_MAX].tolist()
        assert got == expect, f"{mode}: post-split scan diverges at {i}"

    return {
        "ops_per_s": completed / dt,
        "completed": completed,
        "fetches_per_op": float(
            stats[dex_mod.STAT_FETCHES] / max(stats[dex_mod.STAT_OPS], 1)
        ),
        "splits_shed": int(stats[dex_mod.STAT_SPLITS]),
        "smo_splits": int(stats[dex_mod.STAT_SMO_SPLITS]),
        "drains": drains,
        "stat_drains": int(stats[dex_mod.STAT_DRAINS]),
        "shed_lanes": shed_total,
        "onmesh_lanes": onmesh_total,
        "probe_hits": probe_hits,
        "survivor_frac": survivor_frac,
        "n_keys_final": int(hk.size),
    }


def run(quick: bool = False, seed: "int | None" = None):
    s = 0 if seed is None else int(seed)
    n_keys = 24_000 if quick else 48_000
    n_batches = 4 if quick else 10
    n_warm_batches = 1 if quick else 2
    rng = np.random.default_rng(s + 5)
    dataset = ycsb.make_dataset(n_keys, seed=s)

    # insert trace over the lower 80% of the key space (uniform, so load
    # spreads across subtrees); the top decile stays write-free for the
    # survival probe
    lower = dataset[: int(dataset.size * TRACE_FRAC)]
    wl = ycsb.generate(
        "ycsb-load", lower, n_batches * BATCH, theta=0.0, seed=s + 11
    )

    results = {}
    for mode in ("smo", "drain"):
        results[mode] = _run_mode(
            mode, dataset, wl.ops, wl.keys, n_warm_batches, rng
        )

    smo_r, drain_r = results["smo"], results["drain"]
    onmesh_frac = smo_r["onmesh_lanes"] / max(smo_r["shed_lanes"], 1)
    speedup = smo_r["ops_per_s"] / max(drain_r["ops_per_s"], 1e-9)

    # Plane A on the identical trace: write-through DEX with memory-side
    # SMO pricing; the structural split counts of the two planes must agree
    sim_tree = HostBTree(dataset, dataset * 7, fill=FILL, level_m=1,
                         n_mem_servers=4)
    sim_cfg = baselines.dex_write_through(
        n_compute=8, route_dispersion=4, coherence_batch=BATCH,
        n_mem_servers=4, level_m=1, p_admit_leaf=1.0,
        cache_bytes=512 * 4 * 1024, onmesh_smo=True,
    )
    sim = Simulator(sim_tree, sim_cfg, seed=3)
    sim.run(wl.ops, wl.keys)
    sim_totals = sim.totals()
    mesh_splits = smo_r["smo_splits"]
    sim_splits = int(sim_tree.splits)
    split_ratio = mesh_splits / max(sim_splits, 1)

    rows = [
        "mode,metric,value",
        f"smo,ops_per_s,{smo_r['ops_per_s']:.1f}",
        f"drain,ops_per_s,{drain_r['ops_per_s']:.1f}",
        f"smo,speedup_vs_drain,{speedup:.2f}",
        f"smo,fetches_per_op,{smo_r['fetches_per_op']:.4f}",
        f"drain,fetches_per_op,{drain_r['fetches_per_op']:.4f}",
        f"smo,splits_shed,{smo_r['splits_shed']}",
        f"smo,smo_splits,{smo_r['smo_splits']}",
        f"smo,drains,{smo_r['drains']}",
        f"smo,onmesh_frac,{onmesh_frac:.3f}",
        f"smo,probe_hits,{smo_r['probe_hits']}",
        f"smo,survivor_frac,{smo_r['survivor_frac']:.3f}",
        f"drain,splits_shed,{drain_r['splits_shed']}",
        f"drain,drains,{drain_r['drains']}",
        f"drain,probe_hits,{drain_r['probe_hits']}",
        f"sim,smo_inserts,{sim_totals.smo_inserts}",
        f"sim,tree_splits,{sim_splits}",
        f"sim,two_sided_per_op,{sim_totals.two_sided / max(sim_totals.ops, 1):.4f}",
        f"xval,mesh_vs_sim_splits_ratio,{split_ratio:.2f}",
    ]
    summary = {
        "smo_ops_per_s": smo_r["ops_per_s"],
        "drain_ops_per_s": drain_r["ops_per_s"],
        "speedup_vs_drain": speedup,
        "smo_fetches_per_op": smo_r["fetches_per_op"],
        "drain_fetches_per_op": drain_r["fetches_per_op"],
        "onmesh_frac": onmesh_frac,
        "smo_splits": float(mesh_splits),
        "splits_shed": float(smo_r["splits_shed"]),
        "smo_drains": float(smo_r["drains"]),
        "drain_drains": float(drain_r["drains"]),
        "survivor_frac": smo_r["survivor_frac"],
        "sim_splits": float(sim_splits),
    }

    # ---- acceptance claims -------------------------------------------------
    assert smo_r["shed_lanes"] > 0, "trace never reached the split regime"
    assert onmesh_frac >= 0.90, (
        f"on-mesh SMO resolved only {onmesh_frac:.1%} of leaf overflows"
    )
    # surgical invalidation: a settle touches a handful of nodes, never the
    # whole version table (the drain path's cold restart)
    assert smo_r["survivor_frac"] >= 0.90, smo_r["survivor_frac"]
    # untouched warm rows keep serving from cache across splits
    assert smo_r["probe_hits"] >= 512, smo_r["probe_hits"]
    if drain_r["drains"] > 0:
        assert smo_r["drains"] < drain_r["drains"]
    # the two planes count the same structural event on the same trace
    # (same band as before, spelled through the shared drift checker; a
    # sim-side count under 10 is too noisy for a ratio and skips the check)
    if sim_splits >= 10:
        drift.assert_plane_agreement(
            {"smo_splits": mesh_splits},
            {"smo_splits": sim_splits},
            {"smo_splits": drift.ratio(0.4, 2.5)},
            label="fig14meshload structural splits",
        )
    return rows, summary


def main():
    quick = "--quick" in sys.argv
    rows, summary = run(quick=quick)
    print("\n".join(rows))
    for k, v in summary.items():
        print(f"# {k} = {v:.4f}")


if __name__ == "__main__":
    main()
