"""Shared benchmark harness for the paper-reproduction suite.

Scaled-down methodology (paper §8.1 at 1/100 scale, same ratios): bulk-load
``N_KEYS`` records, warm up with ``N_WARM`` ops, measure ``N_OPS`` ops.
Cache sizes are expressed as a fraction of the dataset's node count, exactly
mirroring the paper's cache-bytes / dataset-bytes ratios (256MB of 3.2GB =
8%).  Throughput comes from the calibrated cost model (core/cost_model.py);
verb counts come from the mechanistic simulator (core/sim.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


from repro.core import baselines
from repro.core.cost_model import HardwareModel, ThroughputReport, analyze
from repro.core.sim import HostBTree, Simulator
from repro.data import ycsb

N_KEYS = 200_000          # paper: 200M (1/1000 scale)
N_WARM = 60_000
N_OPS = 40_000
DEFAULT_CACHE_RATIO = 0.08  # paper: 256MB / 3.2GB

#: set by ``benchmarks/run.py --trace-dir``: when non-None, every mesh
#: benchmark's timeline is exported here as ``{name}.metrics_timeline.json``
#: plus a Perfetto-viewable ``{name}.trace.json``
TRACE_DIR: Optional[str] = None

#: finished-timeline summaries accumulated since the last drain; run.py
#: folds these into the module's bench_results.json entry
TELEMETRY: Dict[str, dict] = {}


def drain_telemetry() -> Dict[str, dict]:
    """Return and clear the summaries accumulated by :func:`finish_timeline`."""
    out = dict(TELEMETRY)
    TELEMETRY.clear()
    return out


@dataclasses.dataclass
class BenchResult:
    name: str
    workload: str
    threads: int
    report: ThroughputReport
    per_op: Dict[str, float]

    def row(self) -> str:
        po = self.per_op
        return (
            f"{self.name},{self.workload},{self.threads},"
            f"{self.report.mops():.3f},{self.report.bottleneck},"
            f"{po['reads']:.3f},{po['writes']:.3f},{po['atomics']:.3f},"
            f"{po['two_sided']:.4f},{po['traffic_bytes']:.1f}"
        )


HEADER = (
    "index,workload,threads,mops,bottleneck,reads_per_op,writes_per_op,"
    "atomics_per_op,two_sided_per_op,traffic_bytes_per_op"
)


def seed_kwargs(seed: "int | None") -> dict:
    """Map the bench suite's ``--seed`` to :func:`run_one` /
    :func:`sweep_threads` kwargs.  ``None`` keeps every module's built-in
    defaults (bit-identical to historical runs); an int reseeds both the
    dataset and the workload/simulator streams, so ``bench_results.json``
    is reproducible for any chosen seed."""
    if seed is None:
        return {}
    return {"seed": int(seed) + 7, "dataset_seed": int(seed)}


def run_one(
    system: str,
    workload: str,
    *,
    n_keys: int = N_KEYS,
    n_ops: int = N_OPS,
    n_warm: int = N_WARM,
    cache_ratio: float = DEFAULT_CACHE_RATIO,
    theta: float = 0.99,
    threads: int = 144,
    seed: int = 7,
    dataset_seed: int = 0,
    cfg_overrides: Optional[dict] = None,
    hw: Optional[HardwareModel] = None,
    hot_leaf_fraction: Optional[float] = None,
    scan_len: int = 100,
    scan_len_dist: str = "fixed",
) -> BenchResult:
    dataset = ycsb.make_dataset(n_keys, seed=dataset_seed)
    tree = HostBTree(dataset, fill=0.7, level_m=3, n_mem_servers=4)
    cache_nodes = max(64, int(cache_ratio * tree.num_nodes))
    overrides = dict(cache_bytes=cache_nodes * 1024)
    overrides.update(cfg_overrides or {})
    cfg = baselines.ALL[system](**overrides)
    sim = Simulator(tree, cfg, seed=seed)
    warm = ycsb.generate(workload, dataset, n_warm, theta=theta, seed=seed + 1,
                         scan_len=scan_len, scan_len_dist=scan_len_dist)
    sim.run(warm.ops, warm.keys, scan_len=warm.scan_len, scan_lens=warm.scan_lens)
    sim.reset_counters()
    wl = ycsb.generate(workload, dataset, n_ops, theta=theta, seed=seed + 2,
                       scan_len=scan_len, scan_len_dist=scan_len_dist)
    sim.run(wl.ops, wl.keys, scan_len=wl.scan_len, scan_lens=wl.scan_lens)
    if hot_leaf_fraction is None:
        writes = ycsb.WORKLOADS[workload]
        write_frac = writes[0] + writes[2]
        if theta > 0 and write_frac > 0:
            z = ycsb.ZipfianGenerator(n_keys, theta=theta, seed=3)
            hot_leaf_fraction = z.hottest_fraction() * write_frac
        else:
            hot_leaf_fraction = 0.0
    rep = analyze(
        sim, threads_total=threads, hw=hw,
        hot_leaf_write_fraction=hot_leaf_fraction,
    )
    return BenchResult(
        name=cfg.name, workload=workload, threads=threads,
        report=rep, per_op=sim.totals().per_op(),
    )


def sweep_threads(system: str, workload: str, thread_counts, **kw):
    """Scalability curve (§8.2): the verb mix per op is thread-independent,
    so simulate once and re-analyze the caps at each thread count."""
    from repro.core.cost_model import analyze as _an

    dataset = ycsb.make_dataset(kw.get("n_keys", N_KEYS),
                                seed=kw.get("dataset_seed", 0))
    tree = HostBTree(dataset, fill=0.7, level_m=3, n_mem_servers=4)
    cache_nodes = max(64, int(kw.get("cache_ratio", DEFAULT_CACHE_RATIO) * tree.num_nodes))
    overrides = dict(cache_bytes=cache_nodes * 1024)
    overrides.update(kw.get("cfg_overrides") or {})
    cfg = baselines.ALL[system](**overrides)
    sim = Simulator(tree, cfg, seed=kw.get("seed", 7))
    theta = kw.get("theta", 0.99)
    # workload seeds derive from the base seed (defaults keep the
    # historical 11/12 streams bit-identical)
    warm = ycsb.generate(workload, dataset, kw.get("n_warm", N_WARM),
                         theta=theta, seed=kw.get("seed", 7) + 4)
    sim.run(warm.ops, warm.keys, scan_len=warm.scan_len, scan_lens=warm.scan_lens)
    sim.reset_counters()
    wl = ycsb.generate(workload, dataset, kw.get("n_ops", N_OPS),
                       theta=theta, seed=kw.get("seed", 7) + 5)
    sim.run(wl.ops, wl.keys, scan_len=wl.scan_len, scan_lens=wl.scan_lens)
    mix = ycsb.WORKLOADS[workload]
    write_frac = mix[0] + mix[2]
    hot = 0.0
    if theta > 0 and write_frac > 0:
        hot = ycsb.ZipfianGenerator(
            kw.get("n_keys", N_KEYS), theta=theta, seed=3
        ).hottest_fraction() * write_frac
    out = []
    for t in thread_counts:
        rep = _an(sim, threads_total=t, hw=kw.get("hw"),
                  hot_leaf_write_fraction=hot)
        out.append(BenchResult(
            name=cfg.name, workload=workload, threads=t,
            report=rep, per_op=sim.totals().per_op(),
        ))
    return out


# ---------------------------------------------------------------------------
# Telemetry plumbing (repro/obs): every mesh benchmark accumulates one
# BatchTimeline per measured run and hands it to finish_timeline, which
# embeds the summary in the benchmark's results dict and — when run.py was
# given --trace-dir — exports the per-batch metrics timeline and the
# Chrome/Perfetto trace file
# ---------------------------------------------------------------------------


def timed_batch(fn, *args, **kwargs):
    """Run one mesh dispatch and fence its FULL result tree (not just
    ``state.stats``) before reading the clock; returns ``(result, secs)``.
    Shared timing hygiene for every mesh benchmark — async dispatch cannot
    leak work past the timer."""
    from repro.obs.timeline import timed_call

    return timed_call(fn, *args, **kwargs)


def new_timeline(name: str, **meta):
    """One :class:`repro.obs.timeline.BatchTimeline` for a measured run."""
    from repro.obs.timeline import BatchTimeline

    return BatchTimeline(name, meta=meta)


def finish_timeline(tl, results: Optional[dict] = None) -> dict:
    """Register a finished timeline: its summary lands in
    :data:`TELEMETRY` (drained into bench_results.json by run.py) and, when
    :data:`TRACE_DIR` is set, the per-batch metrics timeline plus the
    Chrome/Perfetto trace are exported there.  Returns the summary dict."""
    import json
    import os

    summary = tl.summary()
    TELEMETRY[tl.name] = summary
    if results is not None:
        results.setdefault("telemetry", {})[tl.name] = summary
    if TRACE_DIR:
        from repro.obs import trace as obs_trace

        os.makedirs(TRACE_DIR, exist_ok=True)
        path = os.path.join(TRACE_DIR, f"{tl.name}.metrics_timeline.json")
        with open(path, "w") as f:
            json.dump(tl.to_json(), f)
        obs_trace.write_trace(
            tl, os.path.join(TRACE_DIR, f"{tl.name}.trace.json")
        )
    return summary


#: opcode -> op-class label for shed-lane retry-latency accounting
_OP_CLASS = {0: "lookup", 1: "update", 2: "insert", 3: "scan", 4: "delete"}


def _record_retries(obs, opc, kk, completed_round, done) -> None:
    """Record batches-to-completion per op class on the telemetry batch."""
    import numpy as np

    from repro.core.nodes import KEY_MAX

    live = kk != KEY_MAX
    opc = np.asarray(opc)
    for code, name in _OP_CLASS.items():
        m = live & (opc == code) & done
        if m.any():
            obs.retry(name, int(completed_round[m].max()))


# ---------------------------------------------------------------------------
# Mesh-plane (Plane B) shed replay, shared by the mesh benchmarks: lanes a
# routing bucket load-sheds are retried (bounded), never silently dropped
# from the op count (fig6_mesh_mixed, fig10_mesh_repartition)
# ---------------------------------------------------------------------------


def lookup_with_retries(lookup, state, put, lk, *, max_retries=4, obs=None):
    """Run a masked mesh lookup batch, replaying load-shed lanes up to
    ``max_retries`` times.  Returns ``(state, found, vals, completed)`` —
    ``completed`` is False only for lanes still shed after the bounded
    replay (inactive KEY_MAX lanes count as completed).  ``obs`` is an
    optional telemetry batch (repro/obs/timeline.py): dispatches become
    fenced phases and retry latency is recorded per op class."""
    import numpy as np
    from repro.core.nodes import KEY_MAX
    from repro.obs.timeline import obs_phase

    done = lk == KEY_MAX
    found = np.zeros(lk.shape, bool)
    vals = np.zeros(lk.shape, np.int64)
    completed_round = np.zeros(lk.shape, np.int32)
    for i in range(max_retries):
        if done.all():
            break
        with obs_phase(obs, "lookup" if i == 0 else f"retry/r{i}") as ph:
            state, f, v, sh = lookup(state, put(np.where(done, KEY_MAX, lk)))
            if ph is not None:
                ph.fence((state, f, v, sh))
        f, v, sh = np.asarray(f), np.asarray(v), np.asarray(sh)
        ok = ~done & ~sh
        found[ok] = f[ok]
        vals[ok] = v[ok]
        completed_round[ok] = i + 1
        done |= ok
    if obs is not None:
        _record_retries(obs, np.zeros(lk.shape, np.int32), lk,
                        completed_round, done)
    return state, found, vals, done


def write_with_retries(write, state, put, wk, wv, *, max_retries=4,
                       obs=None, op_class="update"):
    """Run a masked mesh update/insert batch, replaying STATUS_SHED lanes
    up to ``max_retries`` times.  Returns ``(state, status)`` with the
    final per-lane status (still STATUS_SHED only if retries ran out)."""
    import numpy as np
    from repro.core.nodes import KEY_MAX
    from repro.core.write import STATUS_MISS, STATUS_SHED
    from repro.obs.timeline import obs_phase

    status = np.full(wk.shape, STATUS_MISS, np.int32)
    pending = wk != KEY_MAX
    rounds = 0
    for i in range(max_retries):
        if not pending.any():
            break
        with obs_phase(obs, op_class if i == 0 else f"retry/r{i}") as ph:
            state, r = write(
                state,
                put(np.where(pending, wk, KEY_MAX)),
                put(np.where(pending, wv, 0)),
            )
            if ph is not None:
                ph.fence((state, r))
        r = np.asarray(r)
        settled = pending & (r != STATUS_SHED)
        status[settled] = r[settled]
        pending = pending & (r == STATUS_SHED)
        rounds = i + 1
    status[pending] = STATUS_SHED
    if obs is not None and rounds:
        obs.retry(op_class, rounds)
    return state, status


def engine_with_retries(engine, state, put, opc, kk, vv, *, max_retries=4,
                        obs=None):
    """Run one mixed-op engine batch (core/engine.py), replaying load-shed
    lanes (``EngineResult.shed``) up to ``max_retries`` times.  Returns
    ``(state, found, vals, status, scan_k, scan_v, taken, completed)`` —
    ``completed`` is False only for lanes still shed after the bounded
    replay; ``scan_k``/``scan_v`` are None for engines built without
    ``"scan"``.  Lanes never silently vanish from the op count.  ``obs``
    is an optional telemetry batch (repro/obs/timeline.py): the first
    dispatch becomes a fenced "engine" phase, replays become "retry/rN"
    phases, and batches-to-completion is recorded per op class."""
    import numpy as np
    from repro.core.nodes import KEY_MAX
    from repro.core.write import STATUS_MISS, STATUS_SHED
    from repro.obs.timeline import obs_phase

    done = kk == KEY_MAX
    found = np.zeros(kk.shape, bool)
    vals = np.zeros(kk.shape, np.int64)
    status = np.full(kk.shape, STATUS_MISS, np.int32)
    sk = sv = None
    taken = np.zeros(kk.shape, np.int32)
    completed_round = np.zeros(kk.shape, np.int32)
    for i in range(max_retries):
        if done.all():
            break
        with obs_phase(obs, "engine" if i == 0 else f"retry/r{i}") as ph:
            state, r = engine(
                state,
                put(np.where(done, 0, opc).astype(np.int32)),
                put(np.where(done, KEY_MAX, kk)),
                put(np.where(done, 0, vv)),
            )
            if ph is not None:
                ph.fence((state, r))
        sh = np.asarray(r.shed)
        ok = ~done & ~sh
        found[ok] = np.asarray(r.found)[ok]
        vals[ok] = np.asarray(r.values)[ok]
        status[ok] = np.asarray(r.status)[ok]
        if r.scan_keys is not None:
            if sk is None:
                sk = np.full(np.asarray(r.scan_keys).shape, KEY_MAX, np.int64)
                sv = np.zeros(sk.shape, np.int64)
            sk[ok] = np.asarray(r.scan_keys)[ok]
            sv[ok] = np.asarray(r.scan_values)[ok]
            taken[ok] = np.asarray(r.taken)[ok]
        completed_round[ok] = i + 1
        done |= ok
    status[~done] = STATUS_SHED
    if obs is not None:
        _record_retries(obs, opc, kk, completed_round, done)
    return state, found, vals, status, sk, sv, taken, done


def scan_with_retries(scan, state, put, starts, cnts, *, max_count,
                      max_retries=4, obs=None):
    """Run a masked mesh scan batch, replaying shed lanes (taken == -1) up
    to ``max_retries`` times.  Returns ``(state, keys, vals, taken,
    completed)``."""
    import numpy as np
    from repro.core.nodes import KEY_MAX
    from repro.obs.timeline import obs_phase

    done = starts == KEY_MAX
    out_k = np.full((starts.size, max_count), KEY_MAX, np.int64)
    out_v = np.zeros((starts.size, max_count), np.int64)
    taken = np.zeros(starts.size, np.int32)
    rounds = 0
    for i in range(max_retries):
        if done.all():
            break
        with obs_phase(obs, "scan" if i == 0 else f"retry/r{i}") as ph:
            state, kk, vv, tk = scan(
                state, put(np.where(done, KEY_MAX, starts)), put(cnts)
            )
            if ph is not None:
                ph.fence((state, kk, vv, tk))
        kk, vv, tk = np.asarray(kk), np.asarray(vv), np.asarray(tk)
        ok = ~done & (tk >= 0)
        out_k[ok] = kk[ok]
        out_v[ok] = vv[ok]
        taken[ok] = tk[ok]
        done |= ok
        rounds = i + 1
    if obs is not None and rounds:
        obs.retry("scan", rounds)
    return state, out_k, out_v, taken, done
