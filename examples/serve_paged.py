"""Serving example: batched decode over the DEX-paged KV cache.

A small GQA model serves a batch of requests; KV pages live in a pool whose
page table is the DEX B+-tree (admission = batched index inserts, page-table
resolution = one batched index lookup per step, release = range delete).

Run:  PYTHONPATH=src python examples/serve_paged.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serve.kv_cache import PagedKVCache
from repro.serve.serve_step import paged_decode_step


def main():
    cfg = get_config("minitron-4b").reduced(n_layers=2, d_model=64,
                                            n_heads=4, n_kv_heads=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    page_size = 16
    max_len = 64
    batch = 4
    kv = PagedKVCache(cfg=cfg, n_pages=64, page_size=page_size, max_batch=batch)

    # admit requests (control plane: DEX index inserts)
    req_ids = np.arange(100, 100 + batch)
    for r in req_ids:
        kv.admit_request(int(r), prompt_len=0)
    print(f"admitted {batch} requests; index lookups so far: {kv.lookups}")

    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(batch, 1)), jnp.int32)
    ppr = max_len // page_size

    generated = []
    for step in range(24):
        # grow pages on boundary crossings (control plane)
        for r in req_ids:
            kv.extend_request(int(r))
        table = kv.resolve_tables(req_ids, ppr)       # data plane: DEX lookup
        seq_lens = kv.batch_seq_lens(req_ids)
        logits, k_new, v_new = paged_decode_step(
            cfg, params, tokens, kv.k_pages, kv.v_pages, table, seq_lens,
        )
        kv.append_tokens(req_ids, k_new, v_new)       # scatter into pool
        tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        generated.append(np.asarray(tokens[:, 0]))

    gen = np.stack(generated, axis=1)
    print(f"generated {gen.shape[1]} tokens per request; sample: {gen[0][:10]}")

    freed = sum(kv.release_request(int(r)) for r in req_ids)
    print(f"released all requests: {freed} pages reclaimed "
          f"(free list: {len(kv.free)}/{kv.n_pages}); "
          f"total index lookups: {kv.lookups}")


if __name__ == "__main__":
    main()
