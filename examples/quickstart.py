"""Quickstart: the DEX index end-to-end in five minutes (CPU).

1. bulk-load a B+-tree, run batched lookups/inserts/scans (device plane);
2. run the paper's protocol simulator and print Table-2-style verb counts;
3. spin the mesh plane on however many local devices exist.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax

from repro.core import baselines, btree
from repro.core.cost_model import analyze
from repro.core.sim import HostBTree, Simulator
from repro.data import ycsb


def main():
    # --- 1. the index as a data structure ----------------------------------
    keys = ycsb.make_dataset(100_000, seed=0)
    tree, meta = btree.bulk_build(keys, keys * 10)
    print(f"built B+-tree: {meta.num_nodes} nodes, height {meta.height}")

    probe = keys[::1000]
    found, vals = btree.bulk_lookup(tree, probe, height=meta.height)
    assert bool(np.all(np.asarray(found)))
    print(f"bulk_lookup: {probe.size} keys, all found, "
          f"values ok: {bool(np.all(np.asarray(vals) == probe * 10))}")

    new = keys[:100] + 1
    tree, meta, ok = btree.batch_insert(tree, meta, new, new)
    print(f"batch_insert: {int(np.asarray(ok).sum())}/{new.size} handled")

    out_k, _ = btree.bulk_scan(tree, keys[:4], height=meta.height, count=100)
    print(f"bulk_scan: 4 x 100-record range scans, "
          f"first row starts at {int(out_k[0, 0])}")

    # --- 2. the paper's protocol, simulated --------------------------------
    host = HostBTree(keys, level_m=3, n_mem_servers=4)
    sim = Simulator(host, baselines.dex(
        cache_bytes=max(64, int(0.08 * host.num_nodes)) * 1024
    ), seed=1)
    wl = ycsb.generate("read-intensive", keys, 20_000, seed=2)
    sim.run(wl.ops, wl.keys)
    sim.reset_counters()
    wl = ycsb.generate("read-intensive", keys, 20_000, seed=3)
    sim.run(wl.ops, wl.keys)
    stats = sim.totals().per_op()
    rep = analyze(sim)
    print(
        f"DEX protocol: {stats['reads']:.2f} remote reads/op, "
        f"{stats['traffic_bytes']:.0f} B/op, est. {rep.mops():.1f} Mops "
        f"@144 threads (bottleneck: {rep.bottleneck})"
    )

    # --- 3. same index, mesh plane ------------------------------------------
    n = len(jax.devices())
    print(f"mesh plane: {n} local device(s) — see tests/mesh_check.py for "
          f"the multi-device routing/cache/offload exercise, and "
          f"src/repro/launch/dryrun.py for the 512-chip dry-run")


if __name__ == "__main__":
    main()
