"""YCSB-style comparison: DEX vs the paper's competitors on one workload.

Prints a Fig-6-style mini-table (verb counts + modeled throughput) for a
chosen workload/skew.

Run:  PYTHONPATH=src python examples/ycsb_index.py --workload write-intensive
"""

import argparse

from repro.core import baselines
from repro.core.cost_model import analyze
from repro.core.sim import HostBTree, Simulator
from repro.data import ycsb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="read-intensive",
                    choices=sorted(ycsb.WORKLOADS))
    ap.add_argument("--theta", type=float, default=0.99)
    ap.add_argument("--keys", type=int, default=100_000)
    ap.add_argument("--ops", type=int, default=20_000)
    args = ap.parse_args()

    dataset = ycsb.make_dataset(args.keys, seed=0)
    print(f"{'system':<12} {'Mops':>7} {'reads/op':>9} {'writes/op':>10} "
          f"{'2sided':>8} {'B/op':>7}  bottleneck")
    for system in ["dex", "sherman", "p-sherman", "smart", "p-smart"]:
        tree = HostBTree(dataset, level_m=3, n_mem_servers=4)
        cfg = baselines.ALL[system](
            cache_bytes=max(64, int(0.08 * tree.num_nodes)) * 1024
        )
        sim = Simulator(tree, cfg, seed=1)
        warm = ycsb.generate(args.workload, dataset, args.ops, theta=args.theta,
                             seed=2)
        sim.run(warm.ops, warm.keys)
        sim.reset_counters()
        wl = ycsb.generate(args.workload, dataset, args.ops, theta=args.theta,
                           seed=3)
        sim.run(wl.ops, wl.keys)
        s = sim.totals().per_op()
        rep = analyze(sim)
        print(f"{system:<12} {rep.mops():>7.2f} {s['reads']:>9.2f} "
              f"{s['writes']:>10.2f} {s['two_sided']:>8.4f} "
              f"{s['traffic_bytes']:>7.0f}  {rep.bottleneck}")


if __name__ == "__main__":
    main()
