"""End-to-end training driver example: train a ~100M-parameter dense LM for
a few hundred steps on CPU with checkpointing + fault injection + resume.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import tempfile

from repro.launch.train import build_run, train
from repro.train.fault import FailureInjector, TransientError


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--hundred-m", action="store_true",
                    help="~100M-param config (hours on CPU; the default "
                         "reduced config keeps this example CI-sized — on "
                         "real devices use launch/train.py with full archs)")
    args = ap.parse_args()

    overrides = {}
    if args.hundred_m:
        overrides = dict(n_layers=8, d_model=640, n_heads=8, n_kv_heads=8,
                         d_ff=2560, vocab=32000, head_dim=80)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        run = build_run(
            args.arch, reduce=True, batch=8, seq=128, steps=args.steps,
            ckpt_dir=ckpt_dir,
        )
        if overrides:
            import dataclasses
            from repro.models import model as M
            import jax as _jax
            run.cfg = run.cfg.reduced(**overrides)
            run.params = M.init_params(run.cfg, _jax.random.PRNGKey(0))
            from repro.train.optimizer import init_opt_state
            run.opt_state = init_opt_state(run.params, run.opt_cfg)
        n_params = sum(p.size for p in __import__("jax").tree.leaves(run.params))
        print(f"[example] {args.arch} (reduced): {n_params/1e6:.1f}M params")

        # inject a transient failure mid-run to show retry/restore working
        injector = FailureInjector({args.steps // 2: TransientError})
        losses, watchdog = train(
            run, args.steps, ckpt_every=50, injector=injector, log_every=25,
        )
        assert losses[-1] < losses[0], "loss did not improve"
        print(
            f"[example] done: loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
            f"{watchdog.steps} steps, survived 1 injected failure"
        )


if __name__ == "__main__":
    main()
